"""Cross-mode rerank parity suite — the lockdown for the mesh-complete
rerank path.

Three implementations must produce **bit-for-bit identical** runs and
scores:

  * materialized ``rerank_run`` with the query-blocked ``(Q_block, Cmax, D)``
    gather — at every block size, including the Q_block = 1, Q, and Q+1
    boundaries;
  * the streaming single-device :class:`StreamRerankStage`;
  * the streaming :class:`ShardedStreamRerankStage` on the validator mesh.

Exactness (not allclose) is achievable because every test uses
integer-valued embeddings: a pure-gather encoder over a small-integer table
and small-integer query vectors make every dot product an exactly
representable float32 regardless of reduction order, so XLA-vs-numpy and
sharded-vs-dense differences cannot introduce ulp jitter — any inequality is
a real semantic divergence.  Tie order (duplicate doc ids score exactly
equal) is pinned by the shared stable selection in
``retrieval.rank_candidates``.

The adversarial surface: ragged candidate lists, duplicate doc ids, unknown
doc ids (filtered), empty candidate sets (one query and all queries),
``k > Cmax``, chunk sizes that leave ragged tails, and candidate sets that
leave whole chunks empty (exercising the engine's chunk skipping).
Property-based exploration runs when hypothesis is installed (via the
``hypothesis_compat`` guard); a seeded fuzz loop keeps randomized coverage
in environments without it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as E
from repro.core import retrieval as R
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.core.samplers import SubsetResult
from repro.distributed import compat
from repro.models.biencoder import EncoderSpec
from tests.hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

DIM = 8
VOCAB = 64


def _gather_encode(params, tokens, mask):
    del mask
    return jnp.take(params["table"], tokens[:, 0], axis=0)


def _int_setup(n_docs, n_queries, seed):
    """Integer-valued table/queries: exact float32 scores on every path."""
    rng = np.random.default_rng(seed)
    params = {"table": jnp.asarray(rng.integers(-4, 5, size=(VOCAB, DIM)),
                                   jnp.float32)}
    doc_texts = [[int(i % VOCAB)] for i in range(n_docs)]
    c_emb = jnp.take(params["table"],
                     jnp.asarray([t[0] for t in doc_texts]), axis=0)
    q_emb = jnp.asarray(rng.integers(-4, 5, size=(n_queries, DIM)),
                        jnp.float32)
    return params, doc_texts, c_emb, q_emb


@pytest.fixture(scope="module")
def mesh1():
    """Single-device mesh: routes through the full shard_map machinery
    (sharded specs, axis_index, hierarchical slot merge) deterministically;
    true multi-device behaviour is covered by the subprocess test in
    tests/test_distributed.py."""
    return compat.make_mesh((1,), ("data",))


def _drive_stage(stage, store, params, q_emb):
    """Mirror StreamingEngine's loop, including candidate chunk skipping."""
    carry = stage.init(q_emb)
    for toks, mask, base, n_valid in store.chunks():
        if not stage.wants_chunk(base // store.chunk):
            continue
        carry = stage.step(params, q_emb, carry, toks, mask, base, n_valid)
    return stage.finalize(carry)


def _check_parity(mesh, n_docs, cand_lists, *, k, chunk, seed=0):
    """Assert all rerank modes agree bit-for-bit for one scenario.

    ``cand_lists`` is one candidate-id list per query; ids may repeat, be
    unknown, or be empty lists.
    """
    Q = len(cand_lists)
    params, doc_texts, c_emb, q_emb = _int_setup(n_docs, Q, seed)
    qids = [f"q{i}" for i in range(Q)]
    dids = [f"d{i}" for i in range(n_docs)]
    per_query = {qid: list(c) for qid, c in zip(qids, cand_lists)}

    ref = R.rerank_run(qids, q_emb, dids, c_emb, per_query, k=k,
                       q_block=max(Q, 1))                  # dense gather
    # blocked materialized gather at the boundary block sizes
    for qb in (1, Q, Q + 1, None):
        got = R.rerank_run(qids, q_emb, dids, c_emb, per_query, k=k,
                           q_block=qb)
        assert got == ref, f"blocked rerank_run (q_block={qb}) diverged"

    store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
    single = E.StreamRerankStage(_gather_encode, k=k, query_ids=qids,
                                 doc_ids=dids, per_query=per_query,
                                 store=store)
    assert _drive_stage(single, store, params, q_emb) == ref, \
        "single-device streaming rerank diverged"

    sharded = E.ShardedStreamRerankStage(_gather_encode, mesh, k=k,
                                         query_ids=qids, doc_ids=dids,
                                         per_query=per_query, store=store)
    assert _drive_stage(sharded, store, params, q_emb) == ref, \
        "sharded streaming rerank diverged"
    return ref


# ---------------------------------------------------------------------------
# Deterministic adversarial scenarios
# ---------------------------------------------------------------------------


def test_parity_ragged_duplicate_unknown_empty(mesh1):
    """The kitchen sink: ragged lists, duplicate ids, an unknown id, an
    empty candidate list, and k far above Cmax."""
    run, scores = _check_parity(mesh1, 37, [
        ["d3", "d3", "d10", "d36"],                       # duplicates
        [],                                               # empty
        [f"d{j}" for j in range(20)] + ["nope"],          # ragged + unknown
        ["d36"],                                          # last ragged chunk
        ["d0", "d5", "d5", "d7"],
    ], k=50, chunk=8)
    assert run["q1"] == [] and scores["q1"] == []
    assert len(run["q0"]) == 4                            # dups kept, k > Cmax
    assert len(run["q2"]) == 20                           # unknown filtered


def test_parity_all_queries_empty(mesh1):
    run, scores = _check_parity(mesh1, 12, [[], [], []], k=5, chunk=4)
    assert all(v == [] for v in run.values())
    assert all(v == [] for v in scores.values())


def test_parity_duplicate_tie_order_is_slot_stable(mesh1):
    """Duplicate doc ids score exactly equal; the shared stable selection
    must order them by candidate slot on every path."""
    run, _ = _check_parity(mesh1, 10, [["d2", "d2", "d2"]], k=3, chunk=4)
    assert run["q0"] == ["d2", "d2", "d2"]


@pytest.mark.parametrize("n_docs,chunk,k", [
    (1, 1, 1),        # minimal everything
    (9, 1, 3),        # chunk=1: one row per chunk, heavy skipping
    (16, 16, 100),    # single chunk, k >> candidates
    (23, 7, 2),       # ragged tail, k < Cmax
])
def test_parity_shape_extremes(mesh1, n_docs, chunk, k):
    rng = np.random.default_rng(n_docs)
    cand_lists = [[f"d{j}" for j in rng.integers(0, n_docs, size=m)]
                  for m in (1, 0, min(5, n_docs))]
    _check_parity(mesh1, n_docs, cand_lists, k=k, chunk=chunk, seed=n_docs)


def test_parity_candidates_confined_to_one_chunk(mesh1):
    """Every other chunk is candidate-free: chunk skipping engaged on both
    streaming paths, results still identical to the full materialized run."""
    cand_lists = [["d8", "d9", "d10"], ["d11", "d8"]]
    _check_parity(mesh1, 40, cand_lists, k=10, chunk=8)


def test_rank_candidates_pads_never_surface():
    """k larger than the candidate list must stop at the list, even though
    the score matrix has -inf pad slots."""
    s = np.asarray([[3.0, -np.inf], [1.0, 2.0]], np.float32)
    run, scores = R.rank_candidates(["a", "b"], s, [["x"], ["y", "z"]], k=9)
    assert run == {"a": ["x"], "b": ["z", "y"]}
    assert scores == {"a": [3.0], "b": [2.0, 1.0]}


# ---------------------------------------------------------------------------
# Seeded fuzz (runs everywhere) + hypothesis property (when installed)
# ---------------------------------------------------------------------------


def _random_scenario(rng):
    n_docs = int(rng.integers(1, 41))
    chunk = int(rng.choice([1, 3, 8, 13]))
    Q = int(rng.integers(1, 5))
    cand_lists = []
    for _ in range(Q):
        m = int(rng.integers(0, 9))
        # j can exceed n_docs-1 -> unknown ids; repeats -> duplicates
        cand_lists.append([f"d{int(j)}"
                           for j in rng.integers(0, n_docs + 3, size=m)])
    k = int(rng.integers(1, 61))
    return n_docs, cand_lists, k, chunk


def test_parity_seeded_fuzz(mesh1):
    """Randomized cross-mode sweep that does not need hypothesis — the same
    checker the property test drives, over a fixed seed set."""
    rng = np.random.default_rng(7)
    for i in range(12):
        n_docs, cand_lists, k, chunk = _random_scenario(rng)
        _check_parity(mesh1, n_docs, cand_lists, k=k, chunk=chunk, seed=i)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_parity_property(seed):
    """Hypothesis-driven exploration of the same invariant (skipped when
    hypothesis is absent, see tests/hypothesis_compat.py)."""
    mesh = compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(seed)
    n_docs, cand_lists, k, chunk = _random_scenario(rng)
    _check_parity(mesh, n_docs, cand_lists, k=k, chunk=chunk,
                  seed=seed % 1000)


# ---------------------------------------------------------------------------
# Whole-pipeline parity: streaming (sharded + single) vs blocked materialized
# ---------------------------------------------------------------------------


class _FixedSampler:
    """Pin per-query candidates so all pipelines score the same subset."""

    name = "fixed"

    def __init__(self, per_query):
        self.per_query = per_query

    def sample(self, corpus_ids, run, qrels):
        union = sorted({d for c in self.per_query.values() for d in c
                        if d in set(corpus_ids)})
        return SubsetResult(doc_ids=union, per_query=self.per_query)


def test_pipeline_rerank_all_paths_identical(mesh1):
    """End to end through ValidationPipeline: streaming sharded, streaming
    single-device, and blocked materialized (rerank_block=1 — the worst
    case) produce identical runs, scores, and metrics."""
    n_docs, n_queries = 30, 4
    rng = np.random.default_rng(5)
    params, doc_texts, _, _ = _int_setup(n_docs, n_queries, seed=5)
    corpus = {f"d{i}": doc_texts[i] for i in range(n_docs)}
    queries = {f"q{i}": [int(rng.integers(0, VOCAB))]
               for i in range(n_queries)}
    qrels = {f"q{i}": {f"d{i}": 1} for i in range(n_queries)}
    per_query = {
        "q0": ["d1", "d1", "d4", "d29"],
        "q1": [],
        "q2": [f"d{j}" for j in range(12)],
        "q3": ["d29", "d0"],
    }
    spec = EncoderSpec(
        name="gather", dim=DIM, encode_query=_gather_encode,
        encode_passage=_gather_encode, init=lambda rng: params,
        q_max_len=2, p_max_len=2)

    def pipe(**kw):
        return ValidationPipeline(
            spec, corpus, queries, qrels,
            ValidationConfig(metrics=("MRR@10",), mode="rerank", k=10,
                             batch_size=8, chunk_size=6, **kw),
            sampler=_FixedSampler(per_query))

    outs = {}
    for name, kw in {
        "stream_sharded": dict(mesh=mesh1),
        "stream_single": dict(),
        "mat_blocked": dict(engine="materialized", rerank_block=1),
        "mat_dense": dict(engine="materialized"),
    }.items():
        p = pipe(**kw)
        run, scores, _ = p.engine.run(params)
        outs[name] = (run, scores, p.validate_params(params).metrics)
    ref = outs["mat_dense"]
    for name, got in outs.items():
        assert got == ref, f"{name} diverged from dense materialized"
