"""Validator fleet: the ledger work queue's claim protocol, crash-safe
lease reclaim, multi-process append atomicity, capability matching, the
fleet supervisor's control pump / GC protection, and the satellite fixes
(drain_timeout, watcher high-water cache)."""

import json
import os
import subprocess
import sys
import threading
import time

import jax.numpy as jnp
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.control import ControlConfig, ControlPlane, replay_ledger
from repro.control.metricspec import flatten_rows
from repro.core.jsonl import append_jsonl_atomic, read_jsonl_tolerant
from repro.core.suite import ValidationResult
from repro.core.validator import (AsyncValidator, ValidationLedger,
                                  ValidatorWorker)
from repro.core.watcher import CheckpointWatcher
from repro.core.workqueue import (WorkQueue, WorkUnit, meets,
                                  parse_capabilities, replay)

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


# ---------------------------------------------------------------------------
# WorkUnit / capabilities
# ---------------------------------------------------------------------------

def test_workunit_make_and_requires():
    u = WorkUnit.make(7, "deep", {"mesh_size": 2, "max_depth": 100})
    assert u.key == (7, "deep")
    assert u.requires_dict == {"max_depth": 100, "mesh_size": 2}
    # frozen + hashable: usable as dict keys across queue state
    assert {u: 1}[WorkUnit.make(7, "deep",
                                {"max_depth": 100, "mesh_size": 2})] == 1


def test_meets_numeric_minima_and_equality():
    assert meets({"mesh_size": 8}, {"mesh_size": 2})
    assert not meets({"mesh_size": 1}, {"mesh_size": 2})
    assert meets({"kind": "tpu"}, {"kind": "tpu"})
    assert not meets({"kind": "cpu"}, {"kind": "tpu"})
    assert not meets({}, {"mesh_size": 1})       # undeclared -> fails
    assert meets({}, {})                         # no requirements


def test_parse_capabilities():
    assert parse_capabilities("mesh_size=8,max_depth=100") == {
        "mesh_size": 8, "max_depth": 100}
    assert parse_capabilities("f=0.5, name=tpu") == {"f": 0.5, "name": "tpu"}
    assert parse_capabilities("") == {}
    with pytest.raises(ValueError):
        parse_capabilities("oops")


# ---------------------------------------------------------------------------
# Claim protocol over the shared ledger file
# ---------------------------------------------------------------------------

def _queue(path, wid, **kw):
    kw.setdefault("lease_ttl", 4)
    return WorkQueue(str(path), wid, **kw)


def test_publish_is_idempotent(tmp_path):
    q = _queue(tmp_path / "led.jsonl", "w0")
    units = [WorkUnit.make(1, "a"), WorkUnit.make(1, "b")]
    assert q.publish(units) == units
    assert q.publish(units) == []                # re-publish collapses
    assert sorted(q.state.units) == [(1, "a"), (1, "b")]


def test_claim_conflict_has_single_winner(tmp_path):
    path = tmp_path / "led.jsonl"
    a, b = _queue(path, "A"), _queue(path, "B")
    a.publish([WorkUnit.make(1)])
    unit = a.state.units[(1, "default")].unit
    assert a.try_claim(unit)
    assert not b.try_claim(unit)                 # live lease: bid loses
    # both readers agree on the holder (deterministic fold)
    assert a.refresh().holder(1) == "A"
    assert b.refresh().holder(1) == "A"
    assert any(e["event"] == "claim_lost" for e in b.state.events)


def test_lease_expires_by_sequence_and_is_reclaimed(tmp_path):
    path = tmp_path / "led.jsonl"
    a, b = _queue(path, "A"), _queue(path, "B")
    a.publish([WorkUnit.make(5)])
    unit = a.state.units[(5, "default")].unit
    assert a.try_claim(unit)
    # A dies silently; B's ticks advance the sequence clock (ttl counts
    # records SINCE the claim touched seq 1, so 5 ticks push delta to 5 > 4)
    for _ in range(5):
        assert b.refresh().claimable({}) == []   # lease still live
        b.tick()
    assert b.refresh().claimable({}) == [unit]   # now expired
    assert b.try_claim(unit)
    assert b.state.holder(5) == "B"
    reclaims = [e for e in b.state.events if e["event"] == "reclaim"]
    assert reclaims and reclaims[0]["from"] == "A"


def test_renew_keeps_lease_alive(tmp_path):
    path = tmp_path / "led.jsonl"
    a, b = _queue(path, "A"), _queue(path, "B")
    a.publish([WorkUnit.make(5)])
    unit = a.state.units[(5, "default")].unit
    assert a.try_claim(unit)
    for _ in range(10):                          # far past the ttl
        b.tick()
        a.renew(unit)
    assert b.refresh().claimable({}) == []       # heartbeats held it
    assert b.state.holder(5) == "A"


def test_abandon_reopens_then_fails_past_budget(tmp_path):
    path = tmp_path / "led.jsonl"
    q = _queue(path, "A", max_abandons=1)
    q.publish([WorkUnit.make(2)])
    unit = q.state.units[(2, "default")].unit
    assert q.try_claim(unit)
    q.abandon(unit, error="boom")
    assert q.state.units[(2, "default")].status == "open"   # retryable
    assert q.try_claim(unit)
    q.abandon(unit, error="boom again")
    # distributed retry budget exhausted: failed, no longer claimable
    assert q.state.units[(2, "default")].status == "failed"
    assert q.refresh().claimable({}) == []


def test_result_row_completes_unit_and_capability_filter(tmp_path):
    path = tmp_path / "led.jsonl"
    q = _queue(path, "A", capabilities={"mesh_size": 1})
    q.publish([WorkUnit.make(1, "small"),
               WorkUnit.make(1, "big", {"mesh_size": 8})])
    assert [u.task for u in q.claimable()] == ["small"]     # big filtered
    # a bare result row (e.g. a non-fleet validator sharing the ledger)
    # marks the unit DONE without any claim/complete record
    append_jsonl_atomic(str(path), [{"step": 1, "task": "small",
                                     "metrics": {"MRR@10": 0.5}}])
    assert q.refresh().units[(1, "small")].status == "done"
    assert q.claimable() == []


def test_replay_rederives_online_decisions(tmp_path):
    path = tmp_path / "led.jsonl"
    a, b = _queue(path, "A"), _queue(path, "B")
    a.publish([WorkUnit.make(1), WorkUnit.make(2)])
    u1 = a.state.units[(1, "default")].unit
    u2 = a.state.units[(2, "default")].unit
    assert a.try_claim(u1) and b.try_claim(u2)
    b.complete(u2)
    for _ in range(6):
        b.tick()
    assert b.try_claim(u1)                       # reclaim from dead A
    b.complete(u1)
    offline = replay(str(path), lease_ttl=4)
    assert offline.events == b.refresh().events
    assert offline.completed_units() == [(1, "default"), (2, "default")]


# ---------------------------------------------------------------------------
# Atomic multi-process appends (satellite: subprocess stress test)
# ---------------------------------------------------------------------------

def test_append_jsonl_atomic_repairs_torn_tail(tmp_path):
    path = str(tmp_path / "led.jsonl")
    append_jsonl_atomic(path, [{"a": 1}])
    with open(path, "a") as f:
        f.write('{"torn": tr')                   # crashed writer's fragment
    append_jsonl_atomic(path, [{"b": 2}])
    rows, torn = read_jsonl_tolerant(path)
    assert torn is None                          # fragment was cut, not glued
    assert rows == [{"a": 1}, {"b": 2}]


_APPENDER = """
import sys
sys.path.insert(0, {src!r})
from repro.core.jsonl import append_jsonl_atomic
path, wid = sys.argv[1], sys.argv[2]
for i in range(150):
    append_jsonl_atomic(path, [{{"kind": "tick", "worker": wid, "i": i}}])
"""


def test_multiprocess_appends_never_tear(tmp_path):
    """Two processes hammering one ledger concurrently: every record must
    load intact and per-writer order must hold (O_APPEND atomicity)."""
    path = str(tmp_path / "led.jsonl")
    script = str(tmp_path / "appender.py")
    with open(script, "w") as f:
        f.write(_APPENDER.format(src=SRC))
    procs = [subprocess.Popen([sys.executable, script, path, wid])
             for wid in ("A", "B")]
    assert [p.wait() for p in procs] == [0, 0]
    rows, torn = read_jsonl_tolerant(path)
    assert torn is None
    assert len(rows) == 300                      # nothing lost or torn
    for wid in ("A", "B"):
        seq = [r["i"] for r in rows if r["worker"] == wid]
        assert seq == list(range(150))           # per-writer FIFO


def test_ledger_and_claims_interleave_multiprocess(tmp_path):
    """Claim records and result rows from two processes land in one
    tolerant-loadable ledger; the result-row loader skips claim records."""
    path = str(tmp_path / "led.jsonl")
    script = str(tmp_path / "mixed.py")
    with open(script, "w") as f:
        f.write("""
import sys
sys.path.insert(0, {src!r})
from repro.core.workqueue import WorkQueue, WorkUnit
from repro.core.jsonl import append_jsonl_atomic
path, wid, base = sys.argv[1], sys.argv[2], int(sys.argv[3])
q = WorkQueue(path, wid)
for i in range(25):
    step = base + i
    u = WorkUnit.make(step)
    q.publish([u])
    if q.try_claim(u):
        append_jsonl_atomic(path, [{{"step": step, "task": "default",
                                     "metrics": {{"MRR@10": 0.1}},
                                     "timings": {{}}, "subset_size": 1,
                                     "worker_id": wid}}])
        q.complete(u)
""".format(src=SRC))
    procs = [subprocess.Popen([sys.executable, script, path, wid, base])
             for wid, base in (("A", "0"), ("B", "1000"))]
    assert [p.wait() for p in procs] == [0, 0]
    led = ValidationLedger(path)                 # skips kind-bearing records
    assert len(led.validated_steps) == 50
    state = replay(path)
    assert len(state.completed_units()) == 50


# ---------------------------------------------------------------------------
# In-process fleet: forced crash, reclaim, replay parity, GC protection
# ---------------------------------------------------------------------------

class _FakeFleetPipeline:
    """Deterministic two-task pipeline for fleet mechanics (no encoders)."""

    task_names = ("default", "deep")

    def plan_units(self, step):
        return [WorkUnit.make(step, "default"),
                WorkUnit.make(step, "deep", {"mesh_size": 2})]

    def run_unit(self, params, unit, engine=None, write_runs=None):
        return ValidationResult(
            step=unit.step,
            metrics={"MRR@10": 0.01 * unit.step},
            timings={"total_s": 0.001}, subset_size=3,
            engine="fake", task=unit.task)

    def validate_params(self, params, step=0, engine=None, write_runs=None):
        raise AssertionError("fleet path must go through run_unit")


def _commit_stub_ckpt(root, step):
    ckpt.save(root, step, {"params": {"x": jnp.zeros(1)}})


def _make_worker(root, ledger_path, wid, pipeline, lease_ttl=4):
    queue = WorkQueue(ledger_path, wid, capabilities={"mesh_size": 2},
                      lease_ttl=lease_ttl)
    return ValidatorWorker(
        root, pipeline,
        ledger=ValidationLedger(ledger_path,
                                expected_tasks=pipeline.task_names),
        queue=queue, worker_id=wid,
        params_extractor=lambda state: state["params"])


def test_forced_crash_fleet_reclaim_and_replay(tmp_path):
    """The acceptance scenario: worker A claims a unit and dies mid-unit;
    the survivor B reclaims the expired lease, the step completes with
    EVERY task's row, ControlPlane.replay_ledger reproduces the online
    decision sequence byte-identically, and the claimed checkpoint was
    never GC-eligible while A's lease was live."""
    from repro.launch.fleet import FleetSupervisor

    root = str(tmp_path / "ck")
    ledger_path = str(tmp_path / "ledger.jsonl")
    pipe = _FakeFleetPipeline()
    _commit_stub_ckpt(root, 1)

    ccfg = ControlConfig(metric="MRR@10")
    control = ControlPlane(None, ccfg)
    sup = FleetSupervisor(root, ledger_path, pipe.task_names,
                          control=control, plan_units=pipe.plan_units,
                          lease_ttl=4)
    assert sup.publish_pending() == 2            # both of step 1's units

    worker_a = _make_worker(root, ledger_path, "A", pipe)
    worker_b = _make_worker(root, ledger_path, "B", pipe)

    # A claims the deep unit... and crashes before executing it
    deep = worker_a.queue.refresh().units[(1, "deep")].unit
    assert worker_a.queue.try_claim(deep)

    # while A's lease is live, the checkpoint must be GC-protected
    assert 1 in sup.protect_set()
    assert not sup.step_complete(1)

    # B drains: first the open default unit, then (after the lease ages
    # out through its ticks) the reclaimed deep unit
    for _ in range(30):
        worker_b.run_once()
        sup.pump_control()
        if sup.step_complete(1):
            break
    assert sup.step_complete(1)
    assert [u.key for u in worker_b.completed] == [(1, "default"),
                                                   (1, "deep")]
    reclaims = [e for e in worker_b.queue.state.events
                if e["event"] == "reclaim"]
    assert reclaims and reclaims[0]["from"] == "A" \
        and reclaims[0]["worker"] == "B"

    # every task's row is present, stamped with the surviving worker
    led = ValidationLedger(ledger_path, expected_tasks=pipe.task_names)
    assert led.validated_steps == [1]
    assert {r["worker_id"] for r in led.rows()} == {"B"}

    # step complete + no live claims -> GC may collect it now
    assert 1 not in sup.protect_set()

    # offline fleet replay re-derives the identical decision trace
    offline = replay(ledger_path, lease_ttl=4)
    assert offline.events == worker_b.queue.refresh().events

    # and control-plane replay reproduces the online decisions byte-for-byte
    replayed = replay_ledger(led.rows(), ccfg,
                             expected_tasks=pipe.task_names,
                             group="completion")
    online = [e.to_json() for e in control.events.decisions()]
    assert online  # the completed step WAS observed online
    assert online == [e.to_json() for e in replayed.events.decisions()]


def test_two_workers_split_backlog(tmp_path):
    """Two live workers drain a multi-step backlog cooperatively: every
    unit completes exactly once, and both workers contribute."""
    root = str(tmp_path / "ck")
    ledger_path = str(tmp_path / "ledger.jsonl")
    pipe = _FakeFleetPipeline()
    workers = [_make_worker(root, ledger_path, wid, pipe, lease_ttl=32)
               for wid in ("A", "B")]
    for step in (1, 2, 3):
        _commit_stub_ckpt(root, step)
        workers[0].queue.publish(pipe.plan_units(step))
    for _ in range(40):
        done = sum(w.run_once() for w in workers)
        if not done and not workers[0].queue.refresh().claimable({}):
            break
    state = replay(ledger_path, lease_ttl=32)
    assert len(state.completed_units()) == 6     # 3 steps x 2 tasks
    by_worker = {}
    for r in ValidationLedger(ledger_path).rows():
        by_worker.setdefault(r["worker_id"], []).append(r["step"])
    assert set(by_worker) == {"A", "B"}          # both actually worked
    assert sum(len(v) for v in by_worker.values()) == 6


def test_capability_mismatch_keeps_unit_for_big_worker(tmp_path):
    root = str(tmp_path / "ck")
    ledger_path = str(tmp_path / "ledger.jsonl")
    pipe = _FakeFleetPipeline()
    _commit_stub_ckpt(root, 1)
    small = _make_worker(root, ledger_path, "small", pipe)
    small.queue.capabilities = {"mesh_size": 1}
    big = _make_worker(root, ledger_path, "big", pipe)
    small.queue.publish(pipe.plan_units(1))
    while small.run_once():
        pass
    # the small worker drained what it could; the deep unit is untouched
    assert [u.key for u in small.completed] == [(1, "default")]
    assert big.queue.refresh().units[(1, "deep")].status == "open"
    assert big.run_once() == 1
    assert [u.key for u in big.completed] == [(1, "deep")]


def test_worker_abandons_failing_unit_until_budget(tmp_path):
    class _Failing(_FakeFleetPipeline):
        def run_unit(self, params, unit, engine=None, write_runs=None):
            raise RuntimeError("engine wedged")

    root = str(tmp_path / "ck")
    ledger_path = str(tmp_path / "ledger.jsonl")
    pipe = _Failing()
    _commit_stub_ckpt(root, 1)
    w = _make_worker(root, ledger_path, "A", pipe)
    w.queue.max_abandons = 1
    w.queue.state.max_abandons = 1
    w.queue.publish([WorkUnit.make(1, "default")])
    for _ in range(5):
        w.run_once()
    st = w.queue.refresh().units[(1, "default")]
    assert st.status == "failed"                 # budget exhausted, parked
    assert len(w.errors) == 2                    # initial try + one retry


# ---------------------------------------------------------------------------
# Single-process parity: the fleet refactor must not change solo ledgers
# ---------------------------------------------------------------------------

def test_solo_validator_writes_no_fleet_records(tmp_path):
    """An AsyncValidator without a workqueue must produce rows with neither
    claim records nor worker_id keys — byte-compatible with pre-fleet
    ledgers (and with their replay)."""
    root = str(tmp_path / "ck")
    ledger_path = str(tmp_path / "ledger.jsonl")
    _commit_stub_ckpt(root, 3)

    class _Solo(_FakeFleetPipeline):
        def validate_params(self, params, step=0, engine=None,
                            write_runs=None):
            return self.run_unit(params, WorkUnit.make(step, "default"))

        task_names = ("default",)

    v = AsyncValidator(root, _Solo(), ledger_path=ledger_path,
                       params_extractor=lambda s: s["params"])
    assert v.validate_pending() == 1
    raw, torn = read_jsonl_tolerant(ledger_path)
    assert torn is None
    assert all("kind" not in r and "worker_id" not in r for r in raw)
    # insertion key order matches the pre-fleet writer exactly
    assert list(raw[0]) == ["step", "task", "metrics", "timings",
                            "subset_size", "engine", "score_dtype"]


def test_flatten_rows_completion_grouping_and_worker_ctx():
    rows = [
        {"step": 1, "task": "a", "metrics": {"m": 0.1}, "worker_id": "A",
         "engine": "fake", "score_dtype": "f32"},
        {"step": 2, "task": "a", "metrics": {"m": 0.3}, "worker_id": "B",
         "engine": "fake", "score_dtype": "f32"},
        {"kind": "tick", "worker": "B"},         # claim records are skipped
        {"step": 2, "task": "b", "metrics": {"m": 0.4}, "worker_id": "B",
         "engine": "fake", "score_dtype": "f32"},
        {"step": 1, "task": "b", "metrics": {"m": 0.2}, "worker_id": "B",
         "engine": "fake", "score_dtype": "f32"},
    ]
    # consecutive grouping shreds step 1, whose rows were interleaved
    # (step 2's happened to land adjacently, so it alone survives)
    assert [s for s, _ in flatten_rows(rows, ("a", "b"))] == [2]
    # ...completion grouping emits each step when its LAST task row lands
    obs = flatten_rows(rows, ("a", "b"), with_context=True,
                       group="completion")
    assert [(s, sorted(f)) for s, f, _ in obs] == [
        (2, ["a:m", "b:m"]), (1, ["a:m", "b:m"])]
    assert obs[0][2]["worker_id"] == "B"         # single contributor
    assert obs[1][2]["worker_id"] == "A,B"       # joined like engine
    # pre-fleet rows emit no worker_id key at all
    legacy = flatten_rows([{"step": 1, "task": "a", "metrics": {"m": 1.0},
                            "engine": "e", "score_dtype": "f32"}],
                          ("a",), with_context=True)
    assert "worker_id" not in legacy[0][2]


def test_flatten_rows_completion_requires_expected_tasks():
    with pytest.raises(ValueError, match="completion"):
        flatten_rows([], None, group="completion")
    with pytest.raises(ValueError, match="grouping"):
        flatten_rows([], ("a",), group="bogus")


# ---------------------------------------------------------------------------
# Satellite: stop(drain=True) must not hang on a wedged engine
# ---------------------------------------------------------------------------

def test_stop_drain_timeout_surfaces_wedged_run(tmp_path):
    root = str(tmp_path / "ck")
    _commit_stub_ckpt(root, 1)
    release = threading.Event()

    class _Wedged(_FakeFleetPipeline):
        task_names = ("default",)

        def validate_params(self, params, step=0, engine=None,
                            write_runs=None):
            release.wait(30.0)                  # a stuck device dispatch
            return _FakeFleetPipeline.run_unit(
                self, params, WorkUnit.make(step, "default"))

    v = AsyncValidator(root, _Wedged(),
                       params_extractor=lambda s: s["params"])
    t0 = time.monotonic()
    v.stop(drain=True, drain_timeout=0.3)       # drain hits the wedged run
    assert time.monotonic() - t0 < 5.0          # bounded, not 30s
    assert any(key == "stop" and "timed out" in msg
               for key, msg in v.errors)
    release.set()                               # unwedge the daemon thread


def test_stop_drain_timeout_bounds_wedged_loop_thread(tmp_path):
    root = str(tmp_path / "ck")
    _commit_stub_ckpt(root, 1)
    release = threading.Event()

    class _Wedged(_FakeFleetPipeline):
        task_names = ("default",)

        def validate_params(self, params, step=0, engine=None,
                            write_runs=None):
            release.wait(30.0)
            return _FakeFleetPipeline.run_unit(
                self, params, WorkUnit.make(step, "default"))

    v = AsyncValidator(root, _Wedged(), poll_interval_s=0.01,
                       params_extractor=lambda s: s["params"])
    v.start()
    time.sleep(0.2)                              # loop enters the wedged run
    t0 = time.monotonic()
    v.stop(drain=True, drain_timeout=0.3)
    assert time.monotonic() - t0 < 5.0
    assert any(key == "stop" for key, _ in v.errors)
    release.set()


def test_stop_without_timeout_still_drains(tmp_path):
    root = str(tmp_path / "ck")
    _commit_stub_ckpt(root, 1)

    class _Solo(_FakeFleetPipeline):
        task_names = ("default",)

        def validate_params(self, params, step=0, engine=None,
                            write_runs=None):
            return self.run_unit(params, WorkUnit.make(step, "default"))

    v = AsyncValidator(root, _Solo(),
                       params_extractor=lambda s: s["params"])
    v.start()
    v.stop(drain=True)                           # legacy path: unbounded
    assert v.ledger.validated_steps == [1]


# ---------------------------------------------------------------------------
# Satellite: watcher poll must not re-stat the whole root every tick
# ---------------------------------------------------------------------------

def test_watcher_poll_stats_only_new_entries(tmp_path, monkeypatch):
    """A root with 10k committed step dirs: the first poll pays one stat
    per dir, every later poll pays only for NEW entries."""
    root = tmp_path / "ck"
    root.mkdir()
    for s in range(10_000):
        d = root / f"step_{s:010d}"
        d.mkdir()
        (d / "COMMIT").write_text("{}")          # committed marker

    from repro.core import watcher as watcher_mod
    calls = {"n": 0}
    real = watcher_mod.ckpt.is_committed

    def counting(path):
        calls["n"] += 1
        return real(path)

    monkeypatch.setattr(watcher_mod.ckpt, "is_committed", counting)
    w = CheckpointWatcher(str(root))
    assert len(w.poll()) == 10_000
    assert calls["n"] == 10_000                  # cold poll: one stat each
    calls["n"] = 0
    assert w.poll() == []
    assert calls["n"] == 0                       # warm poll: zero stats
    d = root / f"step_{10_000:010d}"
    d.mkdir()
    (d / "COMMIT").write_text("{}")
    assert w.poll() == [10_000]
    assert calls["n"] == 1                       # only the new dir


def test_watcher_cache_drops_deleted_dirs(tmp_path, monkeypatch):
    """GC'd checkpoint dirs leave the cache, so a re-used step name is
    re-statted instead of trusted stale."""
    root = tmp_path / "ck"
    root.mkdir()
    d = root / "step_0000000001"
    d.mkdir()
    (d / "COMMIT").write_text("{}")
    w = CheckpointWatcher(str(root))
    assert w.poll() == [1]
    import shutil
    shutil.rmtree(d)
    assert w.poll() == []
    d.mkdir()                                    # re-created, NOT committed
    assert w.poll() == []                        # must not trust stale cache
    (d / "COMMIT").write_text("{}")
    w.requeue(1)
    assert w.poll() == [1]


def test_watcher_uncommitted_dir_not_cached(tmp_path):
    root = tmp_path / "ck"
    root.mkdir()
    d = root / "step_0000000007"
    d.mkdir()                                    # trainer mid-write
    w = CheckpointWatcher(str(root))
    assert w.poll() == []
    (d / "COMMIT").write_text("{}")              # commit lands later
    assert w.poll() == [7]


# ---------------------------------------------------------------------------
# Shared TokenStore cache across processes (tentpole assertion)
# ---------------------------------------------------------------------------

def test_mmap_token_cache_shared_across_processes(tmp_path):
    """Two tasks of one step may run in DIFFERENT processes; the mmap
    TokenStore cache + fingerprint makes the shared-corpus case safe: a
    second process maps the same pre-padded bytes instead of rebuilding,
    and reads identical tokens."""
    from repro.core.engine import TokenStore
    texts = [[1, 2, 3], [4, 5], [6]]
    cache = str(tmp_path / "token_cache")
    a = TokenStore.build(texts, max_len=4, chunk=2, backing="mmap",
                         cache_dir=cache)
    assert not a.reused                          # this build created it
    script = str(tmp_path / "reader.py")
    with open(script, "w") as f:
        f.write("""
import sys
sys.path.insert(0, {src!r})
import numpy as np
from repro.core.engine import TokenStore
texts = [[1, 2, 3], [4, 5], [6]]
b = TokenStore.build(texts, max_len=4, chunk=2, backing="mmap",
                     cache_dir={cache!r})
assert b.reused, "second process must map the cache, not rebuild it"
assert b.rebuilt_chunks == 0
np.save(sys.argv[1], np.asarray(b.tokens))
""".format(src=SRC, cache=cache))
    out = str(tmp_path / "tok.npy")
    rc = subprocess.run([sys.executable, script, out]).returncode
    assert rc == 0
    import numpy as np
    assert np.array_equal(np.load(out), np.asarray(a.tokens))


# ---------------------------------------------------------------------------
# Slow tier: real worker subprocesses over real checkpoints
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fleet_filespace(tmp_path_factory):
    """Corpus + queries + qrels + 3 toy checkpoints, shared by the slow
    fleet integration tests (each test gets its own output dir / ledger)."""
    from repro.core.metrics import write_trec_run as _wtr
    from repro.data import corpus as corpus_lib
    base = tmp_path_factory.mktemp("fleet")
    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=200,
                                                n_queries=20)
    cdir = base / "corpus"
    cdir.mkdir()
    corpus_lib.write_jsonl(str(cdir / "split0.jsonl"), ds.corpus)
    qfile = base / "queries.jsonl"
    corpus_lib.write_jsonl(str(qfile), ds.queries)
    qrels = base / "qrels.txt"
    with open(qrels, "w") as f:
        for qid, docs in ds.qrels.items():
            for did, g in docs.items():
                f.write(f"{qid} 0 {did} {g}\n")
    sys.path.insert(0, ROOT)
    from benchmarks.common import toy_spec, train_toy_dr
    spec = toy_spec(ds.vocab)
    ckdir = base / "ckpts"
    _, snaps = train_toy_dr(ds, spec, steps=40, snapshot_every=20)
    for step, params in snaps:
        ckpt.save(str(ckdir), step, {"params": params})
    return {"base": base, "corpus_dir": cdir, "queries": qfile,
            "qrels": qrels, "ckpts": ckdir,
            "n_ckpts": len(ckpt.list_steps(str(ckdir)))}


def _worker_argv(fs, outdir, extra=()):
    return [sys.executable, "-m", "repro.core.cli",
            "--query_file", str(fs["queries"]),
            "--candidate_dir", str(fs["corpus_dir"]),
            "--ckpts_dir", str(fs["ckpts"]),
            "--qrel_file", str(fs["qrels"]),
            "--q_max_len", "10", "--p_max_len", "26",
            "--run_name", "t", "--report_to", "jsonl",
            "--output_dir", str(outdir),
            "--worker", "--lease_ttl", "8",
            "--encoder", "tests.test_cli:toy_encoder_from_cli",
            *extra]


def _worker_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC, ROOT] + env.get("PYTHONPATH", "").split(os.pathsep))
    return env


@pytest.mark.slow
def test_fleet_launcher_two_cli_workers_drain_backlog(fleet_filespace):
    """`python -m repro.launch.fleet --workers 2 -- <cli --worker ...>`:
    two real worker processes split the checkpoint backlog through the
    shared ledger, the launcher reaps them, and the resulting ledger is
    complete, attributed, and fleet-replayable."""
    fs = fleet_filespace
    outdir = fs["base"] / "out_launcher"
    rc = subprocess.run(
        [sys.executable, "-m", "repro.launch.fleet", "--workers", "2",
         "--poll_interval", "0.2", "--"] + _worker_argv(fs, outdir),
        env=_worker_env(), cwd=ROOT, timeout=600).returncode
    assert rc == 0
    ledger_path = str(outdir / "t_ledger.jsonl")
    led = ValidationLedger(ledger_path)
    assert len(led.validated_steps) == fs["n_ckpts"]
    assert all(r.get("worker_id", "").startswith("worker-")
               for r in led.rows())
    state = replay(ledger_path, lease_ttl=8)
    assert len(state.completed_units()) == fs["n_ckpts"]
    # publication was idempotent across both discovering workers
    assert len(state.units) == fs["n_ckpts"]


@pytest.mark.slow
def test_fleet_survives_sigkilled_worker(fleet_filespace):
    """Two real workers; one is SIGKILLed mid-run.  The survivor ticks the
    dead worker's lease out, reclaims its unit, finishes the whole backlog
    and exits 0 — the ledger ends complete with no failed units."""
    fs = fleet_filespace
    outdir = fs["base"] / "out_kill"
    env = _worker_env()
    victim = subprocess.Popen(
        _worker_argv(fs, outdir, ["--worker_id", "victim"]),
        env=env, cwd=ROOT)
    survivor = subprocess.Popen(
        _worker_argv(fs, outdir, ["--worker_id", "survivor"]),
        env=env, cwd=ROOT)
    ledger_path = str(outdir / "t_ledger.jsonl")
    try:
        # let the victim get far enough to (very likely) hold a claim
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline:
            if os.path.exists(ledger_path) and any(
                    r.get("kind") == "claim" and r.get("worker") == "victim"
                    for r in read_jsonl_tolerant(ledger_path)[0]):
                break
            if victim.poll() is not None:
                break               # drained before we could kill it
            time.sleep(0.25)
        victim.kill()
        victim.wait(timeout=30)
        assert survivor.wait(timeout=600) == 0
    finally:
        for p in (victim, survivor):
            if p.poll() is None:
                p.kill()
                p.wait()
    led = ValidationLedger(ledger_path)
    assert len(led.validated_steps) == fs["n_ckpts"]     # nothing lost
    state = replay(ledger_path, lease_ttl=8)
    assert len(state.completed_units()) == fs["n_ckpts"]
    assert not [st for st in state.units.values() if st.status == "failed"]
    # the survivor finished every unit the victim left behind
    by_worker = {r.get("worker_id") for r in led.rows()}
    assert "survivor" in by_worker


# ---------------------------------------------------------------------------
# Serving-tier GC protection: the live index's checkpoint is untouchable
# ---------------------------------------------------------------------------

def test_supervisor_extra_protect_shields_serving_checkpoint(tmp_path):
    """The checkpoint backing the LIVE serving index (and one mid-
    promotion) joins the supervisor's protect_set via extra_protect, even
    after its validation completes — quality GC can never delete the
    checkpoint queries are being answered from."""
    from repro.launch.fleet import FleetSupervisor

    root = str(tmp_path / "ck")
    ledger_path = str(tmp_path / "ledger.jsonl")
    pipe = _FakeFleetPipeline()
    serving = {"steps": set()}           # stands in for Promoter.protect_set
    sup = FleetSupervisor(root, ledger_path, pipe.task_names,
                          plan_units=pipe.plan_units,
                          extra_protect=lambda: serving["steps"])
    w = _make_worker(root, ledger_path, "A", pipe, lease_ttl=32)
    for step in (1, 2):
        _commit_stub_ckpt(root, step)
    sup.publish_pending()
    while w.run_once():
        pass
    assert sup.step_complete(1) and sup.step_complete(2)
    assert sup.protect_set() == set()    # fully validated, GC-eligible...
    serving["steps"] = {1}               # ...until step 1 goes live
    assert sup.protect_set() == {1}
    serving["steps"] = {1, 2}            # live + in-flight promotion
    assert sup.protect_set() == {1, 2}


def test_async_validator_extra_protect_and_gc_end_to_end(tmp_path):
    """End to end through the real promoter: quality-aware gc_checkpoints
    driven by the validator's protect_set keeps the serving checkpoint on
    disk even when its quality rank says delete it."""
    from benchmarks.common import toy_spec, train_toy_dr
    from repro.data import corpus as corpus_lib
    from repro.serve import IndexBuilder, Promoter, QueryService, ServeConfig

    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=80,
                                                n_queries=6)
    spec = toy_spec(ds.vocab)
    _, snaps = train_toy_dr(ds, spec, steps=40, snapshot_every=20)
    root = str(tmp_path / "ck")
    for step, params in snaps:
        ckpt.save(root, step, {"params": params})
    steps = [s for s, _ in snaps]

    builder = IndexBuilder(spec, ds.corpus, ServeConfig(k=5, batch_size=32))
    service = QueryService(spec, k=5, max_batch=4)
    target = {"step": steps[0]}
    promoter = Promoter(builder, service, root,
                        target_fn=lambda: target["step"],
                        log=str(tmp_path / "serve_events.jsonl"))

    class _Done:
        """Pipeline stub: every step counts as fully validated."""
        task_names = ("default",)

    validator = AsyncValidator(root, _Done(),
                               extra_protect=promoter.protect_set)
    for s in steps:
        validator.ledger.record(ValidationResult(
            step=s, metrics={"MRR@10": 0.01 * s},
            timings={"total_s": 0.001}, subset_size=1, engine="fake"))
    assert validator.protect_set() == set()      # all validated, no serving

    assert promoter.poll_once()                  # steps[0] goes live
    assert validator.protect_set() == {steps[0]}

    # quality GC wants to keep only the best step -- but the live one
    # (worst-ranked, steps[0]) must survive through protect_set
    deleted = ckpt.gc_checkpoints(root, keep=[steps[-1]],
                                  protect=validator.protect_set())
    remaining = set(ckpt.list_steps(root))
    assert steps[0] in remaining and steps[-1] in remaining
    assert steps[0] not in deleted
    # the survivor still answers queries from the protected checkpoint
    qid = next(iter(ds.queries))
    assert service.answer([(qid, ds.queries[qid])])[0].step == steps[0]
