"""The paper's CLI surface: splitter + validator, end to end over files."""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.core.metrics import read_trec_run, write_trec_run
from repro.data import corpus as corpus_lib

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def filespace(tmp_path_factory):
    """corpus dir + query file + qrels + baseline run + toy checkpoints."""
    base = tmp_path_factory.mktemp("cli")
    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=300,
                                                n_queries=30)
    cdir = base / "corpus"
    cdir.mkdir()
    corpus_lib.write_jsonl(str(cdir / "split0.jsonl"),
                           dict(list(ds.corpus.items())[:150]))
    corpus_lib.write_jsonl(str(cdir / "split1.jsonl"),
                           dict(list(ds.corpus.items())[150:]))
    qfile = base / "queries.jsonl"
    corpus_lib.write_jsonl(str(qfile), ds.queries)
    qrels = base / "qrels.txt"
    with open(qrels, "w") as f:
        for qid, docs in ds.qrels.items():
            for did, g in docs.items():
                f.write(f"{qid} 0 {did} {g}\n")
    baseline = corpus_lib.lexical_baseline_run(ds, k=50)
    run_path = base / "bm25.trec"
    write_trec_run(str(run_path),
                   {q: [d for d, _ in v] for q, v in baseline.items()},
                   {q: [s for _, s in v] for q, v in baseline.items()},
                   tag="bm25")

    sys.path.insert(0, ROOT)
    from benchmarks.common import toy_spec, train_toy_dr
    spec = toy_spec(ds.vocab)
    ckdir = base / "ckpts"
    _, snaps = train_toy_dr(ds, spec, steps=40, snapshot_every=20)
    for step, params in snaps:
        ckpt.save(str(ckdir), step, {"params": params})
    return {"base": base, "corpus_dir": cdir, "queries": qfile,
            "qrels": qrels, "run": run_path, "ckpts": ckdir, "ds": ds}


def test_splitter_cli(filespace):
    from repro.core.splitter import main
    outdir = filespace["base"] / "subset"
    rc = main(["--candidate_dir", str(filespace["corpus_dir"]),
               "--run_file", str(filespace["run"]),
               "--qrel_file", str(filespace["qrels"]),
               "--output_dir", str(outdir), "--depth", "10"])
    assert rc == 0
    subset = corpus_lib.read_jsonl(str(outdir / "subset_top10.jsonl"))
    assert 0 < len(subset) < 300
    golds = {d for q in filespace["ds"].qrels.values() for d in q}
    assert golds <= set(subset)


def toy_encoder_from_cli(args):
    """--encoder hook used by test_validator_cli."""
    sys.path.insert(0, ROOT)
    from benchmarks.common import toy_spec
    return toy_spec(503)


def test_validator_cli_one_shot(filespace):
    from repro.core.cli import main
    outdir = filespace["base"] / "out"
    rc = main(["--query_file", str(filespace["queries"]),
               "--candidate_dir", str(filespace["corpus_dir"]),
               "--ckpts_dir", str(filespace["ckpts"]),
               "--qrel_file", str(filespace["qrels"]),
               "--q_max_len", "10", "--p_max_len", "26",
               "--metrics", "MRR@10", "Recall@100",
               "--report_to", "csv", "jsonl",
               "--run_name", "t", "--write_run",
               "--output_dir", str(outdir),
               "--run_file", str(filespace["run"]), "--depth", "10",
               "--encoder", "tests.test_cli:toy_encoder_from_cli"])
    assert rc == 0
    assert (outdir / "t_metrics.csv").exists()
    assert (outdir / "t_metrics.jsonl").exists()
    assert (outdir / "t_ledger.jsonl").exists()
    runs = [p for p in os.listdir(outdir) if p.endswith(".trec")]
    assert len(runs) == 3                       # one per checkpoint
    # idempotency: re-running validates nothing new, exits clean
    rc2 = main(["--query_file", str(filespace["queries"]),
                "--candidate_dir", str(filespace["corpus_dir"]),
                "--ckpts_dir", str(filespace["ckpts"]),
                "--qrel_file", str(filespace["qrels"]),
                "--q_max_len", "10", "--p_max_len", "26",
                "--output_dir", str(outdir),
                "--encoder", "tests.test_cli:toy_encoder_from_cli"])
    assert rc2 == 0


def test_validator_cli_staging_and_mmap_flags(filespace):
    """--scan_window / --staging / --token_backing / --mmap_dir are exposed
    and forwarded into ValidationConfig; the mmap token cache lands under
    the output dir and scores match the default in-memory run."""
    import csv

    from repro.core.cli import main

    def read_mrr(outdir):
        with open(outdir / "t_metrics.csv") as f:
            return [row["MRR@10"] for row in csv.DictReader(f)]

    common = ["--query_file", str(filespace["queries"]),
              "--candidate_dir", str(filespace["corpus_dir"]),
              "--ckpts_dir", str(filespace["ckpts"]),
              "--qrel_file", str(filespace["qrels"]),
              "--q_max_len", "10", "--p_max_len", "26",
              "--run_name", "t",
              "--encoder", "tests.test_cli:toy_encoder_from_cli"]
    out_mm = filespace["base"] / "out_mmap"
    rc = main(common + ["--output_dir", str(out_mm),
                        "--scan_window", "4",
                        "--staging", "double_buffered",
                        "--token_backing", "mmap"])
    assert rc == 0
    # default --mmap_dir: <output_dir>/token_cache
    cache = out_mm / "token_cache" / "corpus_tokens"
    assert (cache / "store_meta.json").exists()
    assert (cache / "tokens.int32.bin").exists()
    out_ref = filespace["base"] / "out_ref"
    rc = main(common + ["--output_dir", str(out_ref),
                        "--staging", "sync"])
    assert rc == 0
    assert read_mrr(out_mm) == read_mrr(out_ref)


def test_validator_cli_control_plane_flags(filespace):
    """--keep_top_k / --ensemble_top_k / --early_stop* / --policy budget:
    one-shot validation ranks the checkpoints, prunes storage to top-k,
    soups the survivors into a virtual checkpoint and re-validates it."""
    import json
    import shutil

    from repro.core.cli import main
    outdir = filespace["base"] / "out_ctrl"
    ckdir = filespace["base"] / "ckpts_ctrl"     # GC mutates: use a copy
    if not ckdir.exists():
        shutil.copytree(filespace["ckpts"], ckdir)
    n_before = len(ckpt.list_steps(str(ckdir)))
    assert n_before >= 3
    # a stale STOP verdict from a previous session must be cleared, not
    # re-served to a polling trainer
    os.makedirs(outdir, exist_ok=True)
    with open(outdir / "STOP", "w") as f:
        f.write('{"reason": "stale"}')
    rc = main(["--query_file", str(filespace["queries"]),
               "--candidate_dir", str(filespace["corpus_dir"]),
               "--ckpts_dir", str(ckdir),
               "--qrel_file", str(filespace["qrels"]),
               "--q_max_len", "10", "--p_max_len", "26",
               "--run_name", "t", "--output_dir", str(outdir),
               "--policy", "budget",
               "--keep_top_k", "2", "--ensemble_top_k", "2",
               "--early_stop", "--early_stop_patience", "3",
               "--encoder", "tests.test_cli:toy_encoder_from_cli"])
    assert rc == 0
    # stale marker removed; this session's metrics improve so no new one
    assert not (outdir / "STOP").exists()
    # quality-aware GC pruned to top-2 (the soup joins the ranking too)
    assert len(ckpt.list_steps(str(ckdir))) == 2
    # every decision is on disk as a replayable JSONL event
    with open(outdir / "t_control.jsonl") as f:
        events = [json.loads(l) for l in f if l.strip()]
    kinds = {e["kind"] for e in events}
    assert "select" in kinds and "gc" in kinds and "ensemble" in kinds
    ens = [e for e in events if e["kind"] == "ensemble"][-1]
    # the virtual checkpoint went through the normal validation path
    with open(outdir / "t_ledger.jsonl") as f:
        ledgered = [json.loads(l)["step"] for l in f if l.strip()]
    assert ens["step"] in ledgered


def test_validator_cli_rejects_uncomputed_control_metric(filespace):
    """A typo'd --early_stop_metric must fail at parse time, not KeyError
    inside every controller invocation."""
    from repro.core.cli import main
    with pytest.raises(SystemExit):
        main(["--query_file", str(filespace["queries"]),
              "--candidate_dir", str(filespace["corpus_dir"]),
              "--ckpts_dir", str(filespace["ckpts"]),
              "--qrel_file", str(filespace["qrels"]),
              "--metrics", "MRR@10",
              "--early_stop", "--early_stop_metric", "mrr@10",
              "--encoder", "tests.test_cli:toy_encoder_from_cli"])


def test_validator_cli_rerank_mode(filespace):
    from repro.core.cli import main
    outdir = filespace["base"] / "out_rr"
    rc = main(["--query_file", str(filespace["queries"]),
               "--candidate_dir", str(filespace["corpus_dir"]),
               "--ckpts_dir", str(filespace["ckpts"]),
               "--qrel_file", str(filespace["qrels"]),
               "--q_max_len", "10", "--p_max_len", "26",
               "--mode", "rerank", "--depth", "10",
               "--run_file", str(filespace["run"]),
               "--output_dir", str(outdir), "--max_num_valid", "2",
               "--encoder", "tests.test_cli:toy_encoder_from_cli"])
    assert rc == 0
