"""Serving driver + synthetic data builders."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import synthetic
from repro.models import nn
from repro.models import transformer as tfm


def test_serve_batch_greedy_decode():
    from repro.launch.serve import serve_batch
    cfg = registry.get("qwen2-0.5b").smoke_config()
    params = nn.materialize(tfm.init(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 6)), jnp.int32)
    gen = serve_batch(params, cfg, prompts, gen=5)
    assert gen.shape == (2, 5)
    assert gen.dtype == jnp.int32
    assert (np.asarray(gen) >= 0).all()
    assert (np.asarray(gen) < cfg.vocab_size).all()
    # greedy decode is deterministic
    gen2 = serve_batch(params, cfg, prompts, gen=5)
    np.testing.assert_array_equal(np.asarray(gen), np.asarray(gen2))


def test_serve_matches_incremental_prefill():
    """Generating 4 tokens then re-prefilling prompt+gen reproduces the
    same next-token choice (cache consistency at the serving level)."""
    import dataclasses
    cfg = registry.get("deepseek-v2-lite-16b").smoke_config()
    cfg = dataclasses.replace(cfg, compute_dtype=jnp.float32,
                              moe_capacity_factor=8.0)
    params = nn.materialize(tfm.init(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 5)), jnp.int32)
    from repro.launch.serve import serve_batch
    gen = serve_batch(params, cfg, prompts, gen=4)
    full = jnp.concatenate([prompts, gen[:, :3]], axis=1)
    logits, _ = tfm.prefill(params, cfg, full)
    nxt = int(jnp.argmax(logits[0, -1]))
    assert nxt == int(gen[0, 3])


def test_synthetic_batch_builders():
    rng = np.random.default_rng(0)
    b = synthetic.lm_batch(rng, vocab=97, batch=4, seq=16)
    assert b["tokens"].shape == (4, 16)
    assert (np.asarray(b["tokens"]) < 97).all()

    b = synthetic.biencoder_batch(rng, vocab=97, batch=3, q_len=8, p_len=12,
                                  n_psg=2)
    assert b["q_tokens"].shape == (3, 8)
    assert b["p_tokens"].shape == (3, 2, 12)

    b = synthetic.graph_batch(rng, n_nodes=10, n_edges=30, d_feat=7, n_vars=3)
    assert b["node_feat"].shape == (10, 7)
    assert (np.asarray(b["src"]) < 10).all()

    b = synthetic.batched_molecule_graphs(rng, n_graphs=4, nodes_per=5,
                                          edges_per=8, d_feat=6, n_vars=2)
    assert b["node_feat"].shape == (20, 6)
    # block-diagonal: edges stay within their graph's node range
    src, dst = np.asarray(b["src"]), np.asarray(b["dst"])
    for g in range(4):
        sel = slice(g * 8, (g + 1) * 8)
        assert (src[sel] >= g * 5).all() and (src[sel] < (g + 1) * 5).all()
        assert (dst[sel] >= g * 5).all() and (dst[sel] < (g + 1) * 5).all()

    b = synthetic.sasrec_batch(rng, item_vocab=50, batch=3, seq=7, n_neg=11)
    assert b["hist"].shape == (3, 7) and b["neg_ids"].shape == (11,)

    b = synthetic.bert4rec_batch(rng, item_vocab=50, batch=3, seq=9,
                                 n_mask=2, n_neg=11)
    assert b["mlm_positions"].shape == (3, 2)
    assert (np.asarray(b["mlm_positions"]) < 9).all()

    b = synthetic.mind_batch(rng, item_vocab=50, batch=3, seq=7, n_neg=11)
    assert b["target"].shape == (3,)

    b = synthetic.deepfm_batch(rng, field_vocabs=(5, 9, 13), batch=4,
                               max_hot=2)
    assert b["ids"].shape == (4, 3, 2)
    # global row ids live inside each field's offset range
    offs = np.cumsum([0, 5, 9])
    ids = np.asarray(b["ids"])
    for f, (lo, width) in enumerate(zip(offs, (5, 9, 13))):
        v = ids[:, f]
        assert (v >= lo).all() and (v < lo + width).all()
