"""Per-arch smoke tests (reduced configs) + decode/cache consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import nn
from repro.models import transformer as tfm

LM_ARCHS = ["deepseek-67b", "qwen2-0.5b", "qwen2-72b", "arctic-480b",
            "deepseek-v2-lite-16b"]


def _setup(arch, **overrides):
    cfg = registry.get(arch).smoke_config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    params = nn.materialize(tfm.init(jax.random.PRNGKey(0), cfg))
    return cfg, params


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step(arch):
    cfg, params = _setup(arch)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 1,
                                cfg.vocab_size)
    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        lambda p, b: tfm.lm_loss(p, cfg, b), has_aux=True))(
            params, {"tokens": tokens})
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_output_shapes_no_nan(arch):
    cfg, params = _setup(arch)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (3, 16), 1,
                                cfg.vocab_size)
    hidden, _, _ = jax.jit(lambda p, t: tfm.forward(p, cfg, t))(params, tokens)
    assert hidden.shape == (3, 16, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_matches_full_forward(arch):
    """prefill + decode_step must reproduce the full-sequence logits —
    validates KV caches incl. the MLA absorbed-decode path."""
    cfg, params = _setup(arch, compute_dtype=jnp.float32,
                         moe_capacity_factor=8.0)  # no token dropping
    B, S = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 1,
                                cfg.vocab_size)
    hidden, _, _ = tfm.forward(params, cfg, tokens)
    full_logits = tfm.logits(params, cfg, hidden)          # (B,S,V)

    _, caches = tfm.prefill(params, cfg, tokens[:, :S - 1], max_len=S)
    step_logits, _ = tfm.decode_step(params, cfg, caches, tokens[:, S - 1:],
                                     jnp.asarray(S - 1))
    np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_matches_dense():
    cfg, params = _setup("deepseek-67b", compute_dtype=jnp.float32, q_chunk=5)
    cfg_full = dataclasses.replace(cfg, q_chunk=1024)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 13), 1,
                                cfg.vocab_size)
    h1, _, _ = tfm.forward(params, cfg, tokens)
    h2, _, _ = tfm.forward(params, cfg_full, tokens)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)


def test_chunked_xent_matches_full():
    cfg, params = _setup("qwen2-0.5b", compute_dtype=jnp.float32)
    cfg_chunk = dataclasses.replace(cfg, vocab_chunk=37)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 1,
                                cfg.vocab_size)
    l1, _ = tfm.lm_loss(params, cfg, {"tokens": tokens})
    l2, _ = tfm.lm_loss(params, cfg_chunk, {"tokens": tokens})
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.0 some tokens drop but the layer stays finite."""
    cfg, params = _setup("arctic-480b", moe_capacity_factor=1.0)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 32), 1,
                                cfg.vocab_size)
    loss, _ = tfm.lm_loss(params, cfg, {"tokens": tokens})
    assert np.isfinite(float(loss))


def test_biencoder_encode_normalized():
    cfg, params = _setup("dr-bert-base")
    tokens = jax.random.randint(jax.random.PRNGKey(7), (4, 12), 1,
                                cfg.vocab_size)
    mask = jnp.ones((4, 12), bool)
    for pooling in ("cls", "mean"):
        emb = tfm.encode(params, cfg, tokens, mask, pooling)
        assert emb.shape == (4, cfg.d_model)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(emb), axis=-1),
                                   1.0, rtol=1e-4)


def test_padding_mask_invariance():
    """Padded positions must not change bi-encoder embeddings."""
    cfg, params = _setup("dr-bert-base", compute_dtype=jnp.float32)
    t1 = jax.random.randint(jax.random.PRNGKey(8), (2, 8), 1, cfg.vocab_size)
    pad = jnp.zeros((2, 4), jnp.int32)
    t2 = jnp.concatenate([t1, pad], axis=1)
    m1 = jnp.ones((2, 8), bool)
    m2 = jnp.concatenate([m1, jnp.zeros((2, 4), bool)], axis=1)
    e1 = tfm.encode(params, cfg, t1, m1, "mean")
    e2 = tfm.encode(params, cfg, t2, m2, "mean")
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-5,
                               atol=1e-6)


def test_param_axes_metadata_complete():
    """Every param leaf carries logical axes matching (or prefixed by) ndim."""
    for arch in LM_ARCHS + ["dr-bert-base"]:
        cfg = registry.get(arch).smoke_config()
        shapes, axes = nn.abstract_init(tfm.init, jax.random.PRNGKey(0), cfg)
        flat_s = jax.tree_util.tree_leaves(shapes)
        flat_a = jax.tree_util.tree_leaves(
            axes, is_leaf=lambda x: isinstance(x, tuple))
        assert len(flat_s) == len(flat_a)
        for s, a in zip(flat_s, flat_a):
            assert s.ndim >= len(a), (arch, s.shape, a)
