"""The paper's core loop: watcher policies, samplers, validation pipeline,
async validator (idempotency, crash tolerance, never-blocks-training)."""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.core.reporting import MemoryLogger
from repro.core.samplers import (FullCorpus, QrelPool, RandomSubset,
                                 RerankTopK, RunFileTopK, write_subset_jsonl)
from repro.core.validator import AsyncValidator, ValidationLedger
from repro.core.watcher import CheckpointWatcher, Policy
from repro.data import corpus as synthetic_ds
from repro.models import nn
from repro.models.biencoder import EncoderSpec

# ---------------------------------------------------------------------------
# A tiny deterministic "encoder": bag-of-tokens projected by a param matrix.
# Fast enough to validate dozens of checkpoints in seconds.
# ---------------------------------------------------------------------------

DIM = 32
VOCAB = 503


def _toy_encode(params, tokens, mask):
    table = params["table"]                      # (VOCAB, DIM)
    emb = jnp.take(table, tokens, axis=0)
    m = mask.astype(emb.dtype)[..., None]
    v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
    return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def toy_spec():
    return EncoderSpec(
        name="toy", dim=DIM, encode_query=_toy_encode,
        encode_passage=_toy_encode,
        init=lambda rng: {"table": jax.random.normal(rng, (VOCAB, DIM))},
        q_max_len=10, p_max_len=26)


@pytest.fixture(scope="module")
def ds():
    return synthetic_ds.synthetic_retrieval_dataset(0, n_passages=400,
                                                 n_queries=40, vocab=VOCAB)


@pytest.fixture(scope="module")
def baseline_run(ds):
    return synthetic_ds.lexical_baseline_run(ds, k=50)


# ---------------------------------------------------------------------------
# Watcher
# ---------------------------------------------------------------------------

def test_watcher_fifo_and_mark_seen(tmp_path, ds):
    root = str(tmp_path / "ck")
    w = CheckpointWatcher(root)
    assert w.poll() == []
    for s in (30, 10, 20):
        ckpt.save(root, s, {"x": jnp.zeros(1)})
    assert w.poll() == [10, 20, 30]
    assert w.poll() == []                        # seen once
    ckpt.save(root, 40, {"x": jnp.zeros(1)})
    assert w.poll() == [40]


def test_watcher_latest_first_skips_stale(tmp_path):
    root = str(tmp_path / "ck")
    for s in (1, 2, 3):
        ckpt.save(root, s, {"x": jnp.zeros(1)})
    w = CheckpointWatcher(root, policy=Policy(kind="latest_first"))
    assert w.poll() == [3]
    assert w.poll() == []                        # 1, 2 marked stale


def test_watcher_stride(tmp_path):
    root = str(tmp_path / "ck")
    for s in (10, 15, 20, 25, 30):
        ckpt.save(root, s, {"x": jnp.zeros(1)})
    w = CheckpointWatcher(root, policy=Policy(kind="stride", stride=10))
    assert w.poll() == [10, 20, 30]


def test_watcher_requeue_makes_step_visible_again(tmp_path):
    root = str(tmp_path / "ck")
    for s in (1, 2):
        ckpt.save(root, s, {"x": jnp.zeros(1)})
    w = CheckpointWatcher(root)
    assert w.poll() == [1, 2]
    assert w.poll() == []                        # handed out -> seen
    w.requeue(2)
    assert w.poll() == [2]                       # visible again, 1 stays seen
    w.requeue(99)                                # unknown step: no-op
    assert w.poll() == []


# ---------------------------------------------------------------------------
# Samplers (the paper's splitter + §2 strategies)
# ---------------------------------------------------------------------------

def test_runfile_topk_includes_golds_and_depth(ds, baseline_run):
    sub = RunFileTopK(depth=5).sample(list(ds.corpus), baseline_run, ds.qrels)
    ids = set(sub.doc_ids)
    for qid, golds in ds.qrels.items():
        for d in golds:
            assert d in ids                      # golds always kept
        for d, _ in baseline_run.get(qid, [])[:5]:
            assert d in ids
    assert len(ids) < len(ds.corpus)             # actually a subset


def test_depth_monotonicity(ds, baseline_run):
    sizes = [RunFileTopK(depth=d).sample(list(ds.corpus), baseline_run,
                                         ds.qrels).size
             for d in (1, 5, 20, 100)]
    assert sizes == sorted(sizes)


def test_rerank_topk_per_query_lists(ds, baseline_run):
    sub = RerankTopK(depth=10).sample(list(ds.corpus), baseline_run, ds.qrels)
    assert sub.per_query
    for qid, cands in sub.per_query.items():
        assert len(cands) == len(set(cands))     # de-duplicated
        golds = [d for d, g in ds.qrels.get(qid, {}).items() if g > 0]
        for g in golds:
            assert g in cands


def test_qrel_pool_sampler(ds, baseline_run):
    sub = QrelPool(pool=7).sample(list(ds.corpus), baseline_run, ds.qrels)
    for qid in baseline_run:
        assert len(sub.per_query[qid]) <= 7 + len(ds.qrels.get(qid, {}))


def test_random_subset_keeps_golds(ds):
    sub = RandomSubset(n=50, seed=3).sample(list(ds.corpus), None, ds.qrels)
    golds = {d for q in ds.qrels.values() for d in q}
    assert golds <= set(sub.doc_ids)


def test_write_subset_jsonl_roundtrip(tmp_path, ds, baseline_run):
    from repro.data.corpus import read_jsonl
    sub = RunFileTopK(depth=3).sample(list(ds.corpus), baseline_run, ds.qrels)
    out = str(tmp_path / "subset.jsonl")
    write_subset_jsonl(sub, ds.corpus, out)
    loaded = read_jsonl(out)
    assert set(loaded) == set(sub.doc_ids)
    for did in sub.doc_ids:
        assert loaded[did] == list(map(int, ds.corpus[did]))


# ---------------------------------------------------------------------------
# Pipeline (one-checkpoint validation), all three modes
# ---------------------------------------------------------------------------

def _pipeline(ds, baseline_run, mode="retrieval", sampler=None):
    vcfg = ValidationConfig(metrics=("MRR@10", "Recall@100"), mode=mode,
                            k=100, batch_size=64)
    return ValidationPipeline(toy_spec(), ds.corpus, ds.queries, ds.qrels,
                              vcfg, sampler=sampler, baseline_run=baseline_run)


def test_pipeline_retrieval_mode(ds, baseline_run):
    pipe = _pipeline(ds, baseline_run)
    params = toy_spec().init(jax.random.PRNGKey(0))
    res = pipe.validate_params(params, step=1)
    assert 0.0 <= res.metrics["MRR@10"] <= 1.0
    assert res.subset_size == len(ds.corpus)
    assert res.timings["total_s"] > 0


def test_pipeline_subset_faster_same_trend(ds, baseline_run):
    """Subset validation encodes less and (for this oracle-ish baseline)
    overestimates full-corpus MRR — the paper's Figure-2 structure."""
    params = toy_spec().init(jax.random.PRNGKey(0))
    full = _pipeline(ds, baseline_run).validate_params(params)
    sub = _pipeline(ds, baseline_run,
                    sampler=RunFileTopK(depth=10)).validate_params(params)
    assert sub.subset_size < full.subset_size
    assert sub.metrics["MRR@10"] >= full.metrics["MRR@10"] - 1e-9


def test_pipeline_rerank_and_average_rank_modes(ds, baseline_run):
    params = toy_spec().init(jax.random.PRNGKey(0))
    rr = _pipeline(ds, baseline_run, mode="rerank",
                   sampler=RerankTopK(depth=10)).validate_params(params)
    assert rr.metrics["MRR@10"] >= 0.0
    ar = _pipeline(ds, baseline_run, mode="average_rank",
                   sampler=QrelPool(pool=10)).validate_params(params)
    assert ar.metrics["AverageRank"] >= 1.0


# ---------------------------------------------------------------------------
# AsyncValidator: idempotency, crash tolerance, GC protection
# ---------------------------------------------------------------------------

def _save_toy_ckpt(root, step, seed):
    params = toy_spec().init(jax.random.PRNGKey(seed))
    ckpt.save(root, step, {"params": params, "opt_state": {}},
              extra={"step": step})


def test_validator_validates_all_and_is_idempotent(tmp_path, ds, baseline_run):
    root = str(tmp_path / "ck")
    ledger = str(tmp_path / "ledger.jsonl")
    for s in (10, 20, 30):
        _save_toy_ckpt(root, s, s)
    pipe = _pipeline(ds, baseline_run, sampler=RunFileTopK(depth=5))
    v1 = AsyncValidator(root, pipe, ledger_path=ledger, logger=MemoryLogger())
    assert v1.validate_pending() == 3
    assert v1.ledger.validated_steps == [10, 20, 30]
    # restart: a fresh validator over the same ledger re-validates nothing
    v2 = AsyncValidator(root, pipe, ledger_path=ledger)
    assert v2.validate_pending() == 0


def test_validator_survives_broken_checkpoint(tmp_path, ds, baseline_run):
    root = str(tmp_path / "ck")
    _save_toy_ckpt(root, 1, 1)
    # step 2: committed but structurally broken (garbage manifest arrays)
    ckpt.save(root, 2, {"params": {"wrong": jnp.zeros((3,))}})
    _save_toy_ckpt(root, 3, 3)
    pipe = _pipeline(ds, baseline_run, sampler=RunFileTopK(depth=5))
    v = AsyncValidator(root, pipe)
    n = v.validate_pending()
    assert n == 2                                 # 1 and 3 validated
    assert [e[0] for e in v.errors] == [2]


def test_validator_requeues_transient_failure(tmp_path, ds, baseline_run):
    """A checkpoint whose validation fails transiently (torn read, OOM) must
    NOT be permanently swallowed: it is requeued and succeeds on a later
    poll."""
    root = str(tmp_path / "ck")
    _save_toy_ckpt(root, 5, 5)
    calls = {"n": 0}

    def flaky_extractor(state):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient I/O failure")
        return state["params"]

    pipe = _pipeline(ds, baseline_run, sampler=RunFileTopK(depth=5))
    v = AsyncValidator(root, pipe, params_extractor=flaky_extractor)
    assert v.validate_pending() == 0              # first attempt fails
    assert [e[0] for e in v.errors] == [5]
    assert v.protect_set() == {5}                 # unvalidated -> GC-protected
    assert v.validate_pending() == 1              # requeued step succeeds
    assert v.ledger.validated_steps == [5]
    assert v.protect_set() == set()


def test_validator_gives_up_after_max_retries(tmp_path, ds, baseline_run):
    root = str(tmp_path / "ck")
    _save_toy_ckpt(root, 7, 7)

    def broken_extractor(state):
        raise RuntimeError("permanently broken")

    pipe = _pipeline(ds, baseline_run, sampler=RunFileTopK(depth=5))
    v = AsyncValidator(root, pipe, params_extractor=broken_extractor,
                       max_retries=1)
    for _ in range(4):                            # poll far past the budget
        assert v.validate_pending() == 0
    # 1 initial attempt + 1 retry, then the step is given up on
    assert [e[0] for e in v.errors] == [7, 7]
    assert v.watcher.poll() == []                 # not offered again
    assert v.protect_set() == {7}                 # but still GC-protected


def test_validator_async_thread_and_protect_set(tmp_path, ds, baseline_run):
    root = str(tmp_path / "ck")
    pipe = _pipeline(ds, baseline_run, sampler=RunFileTopK(depth=5))
    v = AsyncValidator(root, pipe, poll_interval_s=0.01,
                       logger=MemoryLogger())
    v.start()
    for s in (5, 15):
        _save_toy_ckpt(root, s, s)
    v.stop(drain=True)                            # drains remaining work
    assert v.ledger.validated_steps == [5, 15]
    assert v.protect_set() == set()               # all validated -> GC free
    _save_toy_ckpt(root, 25, 25)
    assert v.protect_set() == {25}                # unvalidated -> protected


def test_validator_max_num_valid(tmp_path, ds, baseline_run):
    root = str(tmp_path / "ck")
    for s in range(1, 6):
        _save_toy_ckpt(root, s, s)
    pipe = _pipeline(ds, baseline_run, sampler=RunFileTopK(depth=5))
    v = AsyncValidator(root, pipe, max_num_valid=2)
    v.validate_pending()
    assert len(v.results) == 2


def test_ledger_persistence(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    from repro.core.pipeline import ValidationResult
    led = ValidationLedger(path)
    led.record(ValidationResult(step=7, metrics={"MRR@10": 0.5},
                                timings={"total_s": 1.0}, subset_size=10))
    led2 = ValidationLedger(path)
    assert 7 in led2
    assert led2.validated_steps == [7]
