"""The PR-5 public API: component registries, multi-task ValidationSuite,
schema-v2 (step, task) ledger, composite control metrics, the deprecated
ValidationPipeline shim, and the TokenStore chunk-hash manifest.

This file must stay clean under ``-W error::DeprecationWarning`` (a CI job
enforces it): internal code never constructs the deprecated shim, and the
tests that deliberately do wrap it in a warning catcher.
"""

import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.control import (ControlConfig, ControlPlane, MetricSpec,
                           flatten_rows, metric_mode, replay_ledger)
from repro.core import engine as E
from repro.core.registry import (ENGINES, SAMPLERS, STAGES, Registry,
                                 resolve_sampler)
from repro.core.samplers import QrelPool, RerankTopK, RunFileTopK
from repro.core.suite import (SuiteResult, ValidationConfig, ValidationResult,
                              ValidationSuite, ValidationTask)
from repro.core.validator import AsyncValidator, ValidationLedger
from repro.data import corpus as synthetic_ds
from repro.models.biencoder import EncoderSpec

DIM = 16
VOCAB = 211


def _toy_encode(params, tokens, mask):
    emb = jnp.take(params["table"], tokens, axis=0)
    m = mask.astype(emb.dtype)[..., None]
    v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
    return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def toy_spec():
    return EncoderSpec(
        name="toy", dim=DIM, encode_query=_toy_encode,
        encode_passage=_toy_encode,
        init=lambda rng: {"table": jax.random.normal(rng, (VOCAB, DIM))},
        q_max_len=10, p_max_len=26)


@pytest.fixture(scope="module")
def ds():
    return synthetic_ds.synthetic_retrieval_dataset(3, n_passages=160,
                                                    n_queries=20, vocab=VOCAB)


@pytest.fixture(scope="module")
def baseline_run(ds):
    return synthetic_ds.lexical_baseline_run(ds, k=30)


@pytest.fixture(scope="module")
def params():
    return toy_spec().init(jax.random.PRNGKey(0))


def _legacy_pipeline(*args, **kw):
    """Construct the deprecated shim with its warning silenced (so this
    file survives -W error::DeprecationWarning)."""
    from repro.core.pipeline import ValidationPipeline
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return ValidationPipeline(*args, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_decorator_get_and_names():
    reg = Registry("widget")

    @reg.register("alpha")
    def make_alpha():
        return "a"

    reg.register("beta", lambda: "b")
    assert reg.names() == ["alpha", "beta"]
    assert "alpha" in reg and len(reg) == 2
    assert reg.get("alpha") is make_alpha


def test_registry_unknown_name_lists_alternatives():
    reg = Registry("widget")
    reg.register("streaming", object())
    reg.register("materialized", object())
    with pytest.raises(ValueError) as ei:
        reg.get("streming")
    msg = str(ei.value)
    assert "unknown widget 'streming'" in msg
    assert "materialized, streaming" in msg          # sorted alternatives
    assert "did you mean 'streaming'" in msg


def test_registry_duplicate_and_overwrite():
    reg = Registry("widget")
    obj = object()
    reg.register("x", obj)
    reg.register("x", obj)                           # same object: idempotent
    with pytest.raises(ValueError, match="already registered"):
        reg.register("x", object())
    reg.register("x", "replacement", overwrite=True)
    assert reg.get("x") == "replacement"


def test_builtin_registries_populated():
    assert {"streaming", "materialized"} <= set(ENGINES.names())
    assert {"topk_xla", "topk_pallas", "topk_sharded", "rerank",
            "rerank_sharded"} <= set(STAGES.names())
    assert {"full", "run_topk", "qrel_pool", "random",
            "rerank_topk"} <= set(SAMPLERS.names())


def test_resolve_sampler_name_instance_none():
    assert resolve_sampler(None).name == "full"
    assert resolve_sampler("run_topk", depth=7).name == "run_top7"
    inst = RunFileTopK(depth=3)
    assert resolve_sampler(inst) is inst
    with pytest.raises(ValueError, match="unknown sampler"):
        resolve_sampler("bm25ish")


def test_unknown_engine_mode_impl_sampler_errors(ds, baseline_run):
    spec = toy_spec()
    t = ValidationTask("default", ds.corpus, ds.queries, ds.qrels)
    with pytest.raises(ValueError, match="unknown engine 'streaminge'.*"
                       "materialized, streaming"):
        ValidationSuite(spec, [t], ValidationConfig(engine="streaminge")) \
            .engine("default")
    with pytest.raises(ValueError, match="unknown impl.*pallas, xla"):
        ValidationSuite(spec, [t], ValidationConfig(impl="cuda")) \
            .engine("default")
    with pytest.raises(ValueError, match="unknown mode.*average_rank, "
                       "rerank, retrieval"):
        ValidationSuite(spec, [ValidationTask("default", ds.corpus,
                                              ds.queries, ds.qrels,
                                              mode="rarank")])
    with pytest.raises(ValueError, match="unknown sampler"):
        ValidationSuite(spec, [ValidationTask("default", ds.corpus,
                                              ds.queries, ds.qrels,
                                              sampler="nope")])


def test_third_party_engine_registers_without_touching_internals(ds, params):
    calls = {}

    @ENGINES.register("test_null_engine")
    def make_null(spec, store, vcfg):
        calls["built"] = True

        class Null:
            name = "test_null_engine"

            def run(self, params):
                qid = store.query_ids[0]
                return ({qid: [store.doc_ids[0]]}, {qid: [1.0]},
                        {"total_s": 0.0})
        return Null()

    try:
        suite = ValidationSuite(
            toy_spec(), [ValidationTask("default", ds.corpus, ds.queries,
                                        ds.qrels)],
            ValidationConfig(engine="test_null_engine"))
        res = suite.validate_params(params, step=1)
        assert calls["built"]
        assert res.tasks["default"].engine == "test_null_engine"
    finally:
        ENGINES._items.pop("test_null_engine", None)


# ---------------------------------------------------------------------------
# Suite ↔ legacy pipeline parity (bit for bit) + the deprecation shim
# ---------------------------------------------------------------------------

MODES_X_ENGINES = [(m, e) for m in ("retrieval", "rerank", "average_rank")
                   for e in ("streaming", "materialized")]


@pytest.mark.parametrize("mode,engine_name", MODES_X_ENGINES)
def test_single_task_suite_matches_legacy_pipeline(ds, baseline_run, params,
                                                   mode, engine_name):
    spec = toy_spec()
    sampler = {"retrieval": RunFileTopK(depth=5),
               "rerank": RerankTopK(depth=8),
               "average_rank": QrelPool(pool=8)}[mode]
    vcfg = ValidationConfig(metrics=("MRR@10", "Recall@100"), mode=mode,
                            k=50, batch_size=32, engine=engine_name)
    suite = ValidationSuite(spec, [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels, mode=mode,
                       sampler=sampler, baseline_run=baseline_run,
                       metrics=("MRR@10", "Recall@100"), k=50)], vcfg)
    legacy = _legacy_pipeline(spec, ds.corpus, ds.queries, ds.qrels, vcfg,
                              sampler=sampler, baseline_run=baseline_run)
    # identical subsets, engines, raw run/scores, and metrics
    assert legacy.doc_ids == suite.subsets["default"].doc_ids
    run_s, scores_s, _ = suite.engine("default").run(params)
    run_l, scores_l, _ = legacy.engine.run(params)
    assert run_s == run_l
    assert scores_s == scores_l
    rs = suite.validate_params(params, step=3)
    rl = legacy.validate_params(params, step=3)
    assert rs.tasks["default"].metrics == rl.metrics
    assert rs.tasks["default"].subset_size == rl.subset_size
    assert rs.tasks["default"].engine == rl.engine == engine_name
    # the flat view exposes both bare and task-qualified names
    assert rs.metrics["MRR@10"] == rs.metrics["default:MRR@10"] \
        == rl.metrics["MRR@10"]


def test_shim_emits_deprecation_warning_exactly_once(ds):
    import repro.core.pipeline as pipeline_mod
    spec = toy_spec()
    vcfg = ValidationConfig(batch_size=32)
    pipeline_mod._warned = False
    try:
        with pytest.warns(DeprecationWarning, match="ValidationPipeline is "
                          "deprecated"):
            pipeline_mod.ValidationPipeline(spec, ds.corpus, ds.queries,
                                            ds.qrels, vcfg)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            pipeline_mod.ValidationPipeline(spec, ds.corpus, ds.queries,
                                            ds.qrels, vcfg)   # second: silent
    finally:
        pipeline_mod._warned = True


def test_task_inherits_vcfg_mode_metrics_k(ds, baseline_run, params):
    """A task that leaves mode/metrics/k unset inherits the suite config's
    values (the documented single-task migration recipe states them once);
    explicit task values still win."""
    vcfg = ValidationConfig(metrics=("Recall@100",), k=10, batch_size=32)
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels)], vcfg)
    res = suite.validate_params(params)
    assert set(res.tasks["default"].metrics) == {"Recall@100"}
    assert suite.tasks["default"].k == 10
    override = ValidationSuite(toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels,
                       metrics=("MRR@10",), k=5)], vcfg)
    assert set(override.validate_params(params)
               .tasks["default"].metrics) == {"MRR@10"}
    # vcfg.mode inherits too (average_rank appends its metric)
    ar = ValidationSuite(toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels,
                       sampler=QrelPool(pool=8), baseline_run=baseline_run)],
        ValidationConfig(metrics=("MRR@10",), mode="average_rank",
                         batch_size=32))
    assert "AverageRank" in ar.validate_params(params) \
        .tasks["default"].metrics


def test_build_engines_fails_fast_on_config_errors(ds):
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels)],
        ValidationConfig(batch_size=32, staging_depth=0))
    with pytest.raises(ValueError, match="staging_depth"):
        suite.build_engines()


def test_observe_rows_skips_partial_steps_like_rehydrate():
    from repro.control import CheckpointSelector, SelectionConfig
    sel = CheckpointSelector(SelectionConfig(
        metric="0.5*dev:MRR@10 + 0.5*heldout:MRR@10"))
    sel.observe_rows([
        {"step": 1, "task": "dev", "metrics": {"MRR@10": 0.2}},
        {"step": 1, "task": "heldout", "metrics": {"MRR@10": 0.4}},
        {"step": 2, "task": "dev", "metrics": {"MRR@10": 0.9}},  # partial
    ])
    assert sel.best_step == 1                      # partial step 2 skipped


def test_suite_rejects_bad_task_sets(ds):
    t = lambda name: ValidationTask(name, ds.corpus, ds.queries, ds.qrels)
    with pytest.raises(ValueError, match="duplicate task name"):
        ValidationSuite(toy_spec(), [t("a"), t("a")])
    with pytest.raises(ValueError, match="at least one task"):
        ValidationSuite(toy_spec(), [])
    with pytest.raises(ValueError, match="must not contain ':'"):
        t("a:b")
    with pytest.raises(ValueError, match="unknown task"):
        ValidationSuite(toy_spec(), [t("a")]).engine("b")


# ---------------------------------------------------------------------------
# Shared TokenStore cache
# ---------------------------------------------------------------------------

def _query_split(ds):
    qids = sorted(ds.queries)
    cut = len(qids) // 2
    mk = lambda ids: ({q: ds.queries[q] for q in ids},
                      {q: ds.qrels[q] for q in ids if q in ds.qrels})
    return mk(qids[:cut]), mk(qids[cut:])


def test_corpus_sharing_tasks_reuse_one_token_store(ds, params):
    (q1, r1), (q2, r2) = _query_split(ds)
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("dev", ds.corpus, q1, r1),
        ValidationTask("heldout", ds.corpus, q2, r2),
    ], ValidationConfig(batch_size=32))
    e1, e2 = suite.engine("dev"), suite.engine("heldout")
    assert suite.store_builds == 1
    assert e1.doc_store is e2.doc_store            # literally one store
    assert e1 is not e2                            # but per-task engines
    res = suite.validate_params(params, step=1)
    assert set(res.tasks) == {"dev", "heldout"}


def test_distinct_corpora_build_distinct_mmap_stores(ds, tmp_path, params):
    (q1, r1), (q2, r2) = _query_split(ds)
    half = dict(list(ds.corpus.items())[:80])
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("full", ds.corpus, q1, r1),
        ValidationTask("full2", ds.corpus, q2, r2),     # shares with "full"
        ValidationTask("half", half, q2, r2),           # different corpus
    ], ValidationConfig(batch_size=32, token_backing="mmap",
                        mmap_dir=str(tmp_path)))
    # build in REVERSE order: cache-dir indices follow task DECLARATION
    # order, so a different lazy access order cannot remap corpora onto
    # each other's cache dirs (which would defeat the cache every run)
    for name in reversed(suite.task_names):
        suite.engine(name)
    assert suite.store_builds == 2
    # first-declared store keeps the historical dir name; second numbered
    m0 = json.load(open(tmp_path / "corpus_tokens" / "store_meta.json"))
    m1 = json.load(open(tmp_path / "corpus_tokens_1" / "store_meta.json"))
    assert m0["n_texts"] == len(ds.corpus)         # "full" corpus -> index 0
    assert m1["n_texts"] == 80                     # "half" corpus -> index 1
    assert m0["fingerprint"] != m1["fingerprint"]
    # a second suite touching tasks in yet another order reuses both caches
    suite2 = ValidationSuite(toy_spec(), [
        ValidationTask("full", ds.corpus, q1, r1),
        ValidationTask("full2", ds.corpus, q2, r2),
        ValidationTask("half", half, q2, r2),
    ], ValidationConfig(batch_size=32, token_backing="mmap",
                        mmap_dir=str(tmp_path)))
    suite2.engine("half"), suite2.engine("full")
    assert suite2.engine("half").doc_store.reused
    assert suite2.engine("full").doc_store.reused


# ---------------------------------------------------------------------------
# Ledger schema v2: (step, task) rows, v1 migration, crash tolerance
# ---------------------------------------------------------------------------

def _res(step, task="default", mrr=0.5):
    return ValidationResult(step=step, metrics={"MRR@10": mrr},
                            timings={"total_s": 0.01}, subset_size=4,
                            engine="streaming", task=task)


def test_ledger_v2_rows_keyed_step_task(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    led = ValidationLedger(path, expected_tasks=("dev", "heldout"))
    led.record(SuiteResult(step=10, tasks={"dev": _res(10, "dev", 0.4),
                                           "heldout": _res(10, "heldout",
                                                           0.6)}))
    assert led.completed(10) and 10 in led
    assert led.tasks_for(10) == ["dev", "heldout"]
    with open(path) as f:
        recs = [json.loads(l) for l in f]
    assert [(r["step"], r["task"]) for r in recs] == [(10, "dev"),
                                                      (10, "heldout")]
    # partial step (crash between task rows): not completed -> re-validated
    led.record(_res(20, "dev"))
    assert not led.completed(20) and 20 not in led
    assert led.validated_steps == [10]
    led2 = ValidationLedger(path, expected_tasks=("dev", "heldout"))
    assert led2.validated_steps == [10] and not led2.completed(20)


def test_ledger_v1_rows_migrate_to_default_task(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    with open(path, "w") as f:                     # a pre-suite (v1) ledger
        for step, mrr in ((10, 0.3), (20, 0.7)):
            f.write(json.dumps({"step": step, "metrics": {"MRR@10": mrr},
                                "timings": {"total_s": 1.0},
                                "subset_size": 9}) + "\n")
    led = ValidationLedger(path, expected_tasks=("default",))
    assert led.validated_steps == [10, 20]
    assert led.tasks_for(10) == ["default"]
    assert all(r["task"] == "default" for r in led.rows())


def test_ledger_v1_replays_identically_to_v2_default(tmp_path):
    """The same observations through a v1 ledger and a v2 default-task
    ledger must produce byte-identical control decisions."""
    v1 = [{"step": s, "metrics": {"MRR@10": m}}
          for s, m in ((1, .5), (2, .6), (3, .55), (4, .58))]
    v2 = [{"step": s, "task": "default", "metrics": {"MRR@10": m}}
          for s, m in ((1, .5), (2, .6), (3, .55), (4, .58))]
    cfg = ControlConfig(metric="MRR@10", early_stop=True, patience=2)
    d1 = replay_ledger(v1, cfg).events.decisions()
    d2 = replay_ledger(v2, cfg).events.decisions()
    assert d1 == d2
    # and the task-qualified spec sees the same series
    cfgq = ControlConfig(metric="default:MRR@10", early_stop=True, patience=2)
    dq = replay_ledger(v2, cfgq).events.decisions()
    assert [(e.kind, e.step) for e in dq] == [(e.kind, e.step) for e in d1]


def test_ledger_tolerates_torn_final_line(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    led = ValidationLedger(path)
    led.record(_res(10))
    led.record(_res(20))
    whole = open(path).read()
    with open(path, "w") as f:                     # crash mid-append
        f.write(whole + '{"step": 30, "metrics": {"MRR@')
    led2 = ValidationLedger(path, expected_tasks=("default",))
    assert led2.validated_steps == [10, 20]        # torn row dropped
    assert 30 not in led2                          # -> will re-validate
    # loading is read-only: an offline audit must never mutate a (possibly
    # live) ledger; only the owning writer repairs the tail, on append
    assert open(path).read() == whole + '{"step": 30, "metrics": {"MRR@'
    led2.record(_res(30))                          # truncates, then appends
    assert ValidationLedger(path).validated_steps == [10, 20, 30]


def test_ledger_raises_on_mid_file_corruption(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    with open(path, "w") as f:
        f.write('{"step": 1, "metrics": {}}\n')
        f.write('{"step": 2, "metr\n')             # torn NON-final line
        f.write('{"step": 3, "metrics": {}}\n')
    with pytest.raises(ValueError, match="corrupt ledger row at .*:2"):
        ValidationLedger(path)


# ---------------------------------------------------------------------------
# Composite metric specs
# ---------------------------------------------------------------------------

def test_metric_spec_parse_and_value():
    flat = {"MRR@10": 0.5, "dev:MRR@10": 0.4, "heldout:MRR@10": 0.8}
    assert MetricSpec.parse("MRR@10").value(flat) == 0.5
    assert MetricSpec.parse("dev:MRR@10").value(flat) == 0.4
    agg = MetricSpec.parse("0.25*dev:MRR@10 + 0.75*heldout:MRR@10")
    assert agg.composite and agg.keys() == ["dev:MRR@10", "heldout:MRR@10"]
    assert agg.value(flat) == pytest.approx(0.25 * 0.4 + 0.75 * 0.8)
    # exact-key override wins (the plane's EMA smoothing bridge)
    assert agg.value({**flat, agg.raw: 0.123}) == 0.123
    with pytest.raises(KeyError, match="'dev:nDCG@10'.*not in"):
        MetricSpec.parse("dev:nDCG@10").value(flat)
    for bad in ("", "  ", "x+", "a**b", "q*MRR@10"):
        with pytest.raises(ValueError):
            MetricSpec.parse(bad)


def test_metric_mode_inference():
    assert metric_mode("MRR@10") == "max"
    assert metric_mode("AverageRank") == "min"
    assert metric_mode("dev:AverageRank + heldout:AverageRank") == "min"
    assert metric_mode("0.5*dev:AverageRank + 0.5*heldout:MRR@10") == "max"


def test_flatten_rows_groups_consecutive_steps():
    rows = [
        {"step": 1, "metrics": {"MRR@10": 0.1}},                  # v1 row
        {"step": 2, "task": "dev", "metrics": {"MRR@10": 0.2}},
        {"step": 2, "task": "heldout", "metrics": {"MRR@10": 0.3}},
        {"step": 1, "task": "dev", "metrics": {"MRR@10": 0.4}},   # revisit
    ]
    flat = flatten_rows(rows)
    assert [s for s, _ in flat] == [1, 2, 1]       # revisit stays separate
    assert flat[0][1] == {"MRR@10": 0.1, "default:MRR@10": 0.1}
    assert flat[1][1] == {"dev:MRR@10": 0.2, "heldout:MRR@10": 0.3}
    # expected_tasks drops partial groups even when their rows would
    # satisfy a spec (the online controller never observed them)
    flat = flatten_rows(rows, expected_tasks=("dev", "heldout"))
    assert [s for s, _ in flat] == [2]


def test_rehydrate_drops_spec_satisfying_partial_steps():
    """A crash-torn step whose SURVIVING rows happen to satisfy the control
    spec must still be dropped when the task set is known — otherwise the
    step is observed twice (rehydrate + its re-validation) and EMA/patience
    diverge from a crash-free run."""
    rows = [
        {"step": 1, "task": "a", "metrics": {"MRR@10": 0.2}},
        {"step": 1, "task": "b", "metrics": {"MRR@10": 0.2}},
        {"step": 2, "task": "a", "metrics": {"MRR@10": 0.9}},  # torn: no b
    ]
    cfg = ControlConfig(metric="a:MRR@10", ema=0.5)
    plane = ControlPlane(None, cfg)
    assert plane.rehydrate(rows, expected_tasks=("a", "b")) == 1
    assert plane.selector.best_step == 1           # partial step 2 unseen
    offline = replay_ledger(rows, cfg, expected_tasks=("a", "b"))
    assert offline.selector.best_step == 1


def test_task_named_sampler_honours_sampler_depth(ds, baseline_run):
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels,
                       sampler="run_topk", sampler_depth=5,
                       baseline_run=baseline_run)],
        ValidationConfig(batch_size=32))
    ref = RunFileTopK(depth=5).sample(list(ds.corpus), baseline_run,
                                      ds.qrels)
    assert suite.subsets["default"].doc_ids == ref.doc_ids
    assert suite.sampler_names["default"] == "run_top5"


def test_logger_schema_has_no_default_duplicates(tmp_path, ds, params):
    from repro.core.reporting import MemoryLogger
    root = str(tmp_path / "ck")
    ckpt.save(root, 1, {"params": params})
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels)],
        ValidationConfig(metrics=("MRR@10",), batch_size=32))
    logger = MemoryLogger()
    v = AsyncValidator(root, suite, logger=logger)
    v.validate_pending()
    _, logged = logger.records[0]
    assert "MRR@10" in logged                      # legacy column intact
    assert not any(k.startswith("default:") for k in logged)
    # the control plane still sees both spellings
    assert "default:MRR@10" in v.results[0].metrics


# ---------------------------------------------------------------------------
# Multi-task end to end: AsyncValidator + control plane on a composite spec
# ---------------------------------------------------------------------------

def test_multi_task_async_validation_end_to_end(tmp_path, ds, params):
    (q1, r1), (q2, r2) = _query_split(ds)
    spec = toy_spec()
    root = str(tmp_path / "ck")
    # 5 checkpoints with IDENTICAL weights: the composite metric plateaus
    # immediately, so patience=2 stops at the 3rd evaluation.
    for s in (10, 20, 30, 40, 50):
        ckpt.save(root, s, {"params": params})

    suite = ValidationSuite(spec, [
        ValidationTask("dev", ds.corpus, q1, r1, metrics=("MRR@10",)),
        ValidationTask("heldout", ds.corpus, q2, r2, metrics=("MRR@10",)),
    ], ValidationConfig(batch_size=32))
    cmetric = "0.5*dev:MRR@10 + 0.5*heldout:MRR@10"
    stop_path = str(tmp_path / "STOP")
    control = ControlPlane(root,
                           ControlConfig(metric=cmetric, mode="max",
                                         keep_top_k=2, early_stop=True,
                                         patience=2),
                           stop_path=stop_path,
                           event_path=str(tmp_path / "control.jsonl"))
    ledger_path = str(tmp_path / "ledger.jsonl")
    v = AsyncValidator(root, suite, controller=control,
                       ledger_path=ledger_path)
    n = v.validate_pending()
    assert n == 5 and not v.errors
    # per-task rows keyed (step, task), two per step, in pass order
    with open(ledger_path) as f:
        recs = [json.loads(l) for l in f if l.strip()]
    assert [(r["step"], r["task"]) for r in recs[:4]] == \
        [(10, "dev"), (10, "heldout"), (20, "dev"), (20, "heldout")]
    assert v.ledger.validated_steps == [10, 20, 30, 40, 50]
    # composite early stop: plateau after 2 non-improving evals -> marker
    assert control.stopped and control.earlystop.reason == "plateau"
    assert os.path.exists(stop_path)
    # quality-aware GC on the composite metric: top-2 (ties -> later step)
    assert ckpt.list_steps(root) == [40, 50]
    # offline replay over the per-task ledger re-derives the decisions
    offline = replay_ledger(v.ledger.rows(), control.cfg)
    assert offline.events.decisions() == control.events.decisions()
    # a restarted validator over the same ledger re-validates nothing
    suite2 = ValidationSuite(spec, [
        ValidationTask("dev", ds.corpus, q1, r1, metrics=("MRR@10",)),
        ValidationTask("heldout", ds.corpus, q2, r2, metrics=("MRR@10",)),
    ], ValidationConfig(batch_size=32))
    v2 = AsyncValidator(root, suite2, ledger_path=ledger_path)
    assert v2.validate_pending() == 0


def test_partial_step_revalidates_missing_tasks(tmp_path, ds, params):
    (q1, r1), (q2, r2) = _query_split(ds)
    root = str(tmp_path / "ck")
    ckpt.save(root, 7, {"params": params})
    ledger_path = str(tmp_path / "ledger.jsonl")
    with open(ledger_path, "w") as f:              # crash left only one task
        f.write(json.dumps({"step": 7, "task": "dev",
                            "metrics": {"MRR@10": 0.1}}) + "\n")
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("dev", ds.corpus, q1, r1, metrics=("MRR@10",)),
        ValidationTask("heldout", ds.corpus, q2, r2, metrics=("MRR@10",)),
    ], ValidationConfig(batch_size=32))
    v = AsyncValidator(root, suite, ledger_path=ledger_path)
    assert v.validate_pending() == 1               # step 7 re-validated
    assert v.ledger.tasks_for(7) == ["dev", "heldout"]


def test_engine_override_rejected_on_multi_task_suite(ds, params):
    """A single injected engine serves exactly one task's data; silently
    scoring every task with it would ledger garbage for the others."""
    (q1, r1), (q2, r2) = _query_split(ds)
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("dev", ds.corpus, q1, r1),
        ValidationTask("heldout", ds.corpus, q2, r2),
    ], ValidationConfig(batch_size=32))

    class Fake:
        name = "fake"

        def run(self, params):
            return {}, {}, {"total_s": 0.0}

    with pytest.raises(ValueError, match="multi-task suite"):
        suite.validate_params(params, engine=Fake())
    # per-task injection is the supported spelling
    suite2 = ValidationSuite(toy_spec(), [
        ValidationTask("dev", ds.corpus, q1, r1),
        ValidationTask("heldout", ds.corpus, q2, r2),
    ], ValidationConfig(batch_size=32),
        engines={"dev": Fake(), "heldout": Fake()})
    res = suite2.validate_params(params)
    assert {r.engine for r in res.tasks.values()} == {"fake"}


def test_registered_engine_opts_into_shared_stores(ds, params):
    """Third-party engines get the suite's TokenStore sharing by declaring
    `uses_token_stores = True` on their factory — no internal edits."""
    from repro.core.engine import make_streaming_engine

    def make_alias(spec, store, vcfg):
        return make_streaming_engine(spec, store, vcfg)
    make_alias.uses_token_stores = True
    ENGINES.register("test_alias_streaming", make_alias)
    try:
        (q1, r1), (q2, r2) = _query_split(ds)
        suite = ValidationSuite(toy_spec(), [
            ValidationTask("dev", ds.corpus, q1, r1),
            ValidationTask("heldout", ds.corpus, q2, r2),
        ], ValidationConfig(batch_size=32, engine="test_alias_streaming"))
        e1, e2 = suite.engine("dev"), suite.engine("heldout")
        assert suite.store_builds == 1
        assert e1.doc_store is e2.doc_store
    finally:
        ENGINES._items.pop("test_alias_streaming", None)


def test_rehydrate_skips_partial_step_and_rerecord_regroups(tmp_path, ds,
                                                            params):
    """A crash between a suite's task rows must not poison restart: the
    composite-spec selector skips the partial observation, and once the
    step re-validates its rows form one fresh CONSECUTIVE block so replay
    sees a single complete observation."""
    (q1, r1), (q2, r2) = _query_split(ds)
    root = str(tmp_path / "ck")
    ckpt.save(root, 7, {"params": params})
    ledger_path = str(tmp_path / "ledger.jsonl")
    with open(ledger_path, "w") as f:              # crash left only one task
        f.write(json.dumps({"step": 5, "task": "dev",
                            "metrics": {"MRR@10": 0.1}}) + "\n")
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("dev", ds.corpus, q1, r1, metrics=("MRR@10",)),
        ValidationTask("heldout", ds.corpus, q2, r2, metrics=("MRR@10",)),
    ], ValidationConfig(batch_size=32))
    cfg = ControlConfig(metric="0.5*dev:MRR@10 + 0.5*heldout:MRR@10",
                        keep_top_k=2)
    control = ControlPlane(root, cfg,
                           event_path=str(tmp_path / "control.jsonl"))
    v = AsyncValidator(root, suite, controller=control,
                       ledger_path=ledger_path)
    # startup rehydrate over the poisoned ledger must not raise, and must
    # observe nothing (the partial step lacks the spec's heldout metric)
    assert control.rehydrate(v.ledger.rows()) == 0
    # ckpt 5 is gone from disk, but the partial step is re-recordable: a
    # fresh suite pass over it regroups the rows at the tail
    res = suite.validate_params(params, step=5)
    v.ledger.record(res)
    rows = v.ledger.rows()
    assert [(r["step"], r["task"]) for r in rows] == [(5, "dev"),
                                                      (5, "heldout")]
    # and offline replay on the repaired ledger sees one full observation
    offline = replay_ledger(rows, cfg)
    assert offline.selector.best_step == 5


def test_control_event_log_tolerates_torn_final_line(tmp_path):
    from repro.control import ControlEventLog
    path = str(tmp_path / "events.jsonl")
    log = ControlEventLog(path)
    log.emit("select", 1, value=0.5)
    log.emit("select", 2, value=0.6)
    whole = open(path).read()
    with open(path, "w") as f:                     # crash mid-append
        f.write(whole + '{"seq": 2, "kind": "sel')
    log2 = ControlEventLog(path)
    assert [e.step for e in log2.events()] == [1, 2]
    log2.emit("select", 3, value=0.7)              # clean line, not glued
    assert [e.step for e in ControlEventLog(path).events()] == [1, 2, 3]
    with open(path, "w") as f:                     # mid-file corruption
        f.write('{"seq": 0, "kind"\n' + whole)
    with pytest.raises(ValueError, match="corrupt control event"):
        ControlEventLog(path)


def test_validate_step_ignores_max_num_valid_cap(tmp_path, ds, params):
    """The soup-scoring path: an explicit validate_step must run even when
    the watcher-driven budget is exhausted."""
    root = str(tmp_path / "ck")
    for s in (1, 2, 3):
        ckpt.save(root, s, {"params": params})
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels,
                       metrics=("MRR@10",))], ValidationConfig(batch_size=32))
    v = AsyncValidator(root, suite, max_num_valid=2)
    v.validate_pending()
    assert len(v.results) == 2                     # budget hit
    assert v.validate_step(3) == 1                 # explicit request still runs
    assert 3 in v.ledger.validated_steps


def test_write_runs_override_protects_real_run_files(tmp_path, ds,
                                                     baseline_run, params):
    outdir = str(tmp_path / "runs")
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels,
                       sampler=RunFileTopK(depth=5),
                       baseline_run=baseline_run, metrics=("MRR@10",))],
        ValidationConfig(batch_size=32, write_run=True, output_dir=outdir))
    suite.validate_params(params, step=0)
    trec = os.path.join(outdir, "asyncval_step0.trec")
    before = open(trec).read()
    # a scoring pass (ensemble soup candidate) must not touch run files
    other = toy_spec().init(jax.random.PRNGKey(9))
    suite.validate_params(other, write_runs=False)
    assert open(trec).read() == before


# ---------------------------------------------------------------------------
# TokenStore chunk-hash manifest (O(changed chunks) full-fidelity rebuild)
# ---------------------------------------------------------------------------

def _texts(n, seed=0, length=6):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(1, 50, size=length))) for _ in range(n)]


def test_full_fingerprint_incremental_rebuild(tmp_path):
    cache = str(tmp_path / "store")
    texts = _texts(40)
    st = E.TokenStore.build(texts, max_len=8, chunk=8, backing="mmap",
                            cache_dir=cache, fingerprint="full")
    assert st.n_chunks == 5 and st.rebuilt_chunks == 5 and not st.reused
    assert os.path.exists(os.path.join(cache, "chunk_hashes.json"))
    # clean rebuild: nothing re-padded
    st2 = E.TokenStore.build(texts, max_len=8, chunk=8, backing="mmap",
                             cache_dir=cache, fingerprint="full")
    assert st2.reused and st2.rebuilt_chunks == 0
    # mutate ONE middle text -> exactly its chunk rebuilds
    texts[19] = [44, 45, 46]                       # chunk 2
    st3 = E.TokenStore.build(texts, max_len=8, chunk=8, backing="mmap",
                             cache_dir=cache, fingerprint="full")
    assert not st3.reused and st3.rebuilt_chunks == 1
    ref = E.TokenStore.build(texts, max_len=8, chunk=8)   # memory reference
    assert np.array_equal(np.asarray(st3.tokens), ref.tokens)
    assert np.array_equal(np.asarray(st3.mask), ref.mask)
    # and the repaired cache is a clean hit again
    st4 = E.TokenStore.build(texts, max_len=8, chunk=8, backing="mmap",
                             cache_dir=cache, fingerprint="full")
    assert st4.reused and st4.rebuilt_chunks == 0


def test_fast_rebuild_invalidates_stale_manifest(tmp_path):
    """A fast-mode rebuild rewrites the bins without a manifest; leaving the
    old manifest behind could later bless stale chunks, so it must go."""
    cache = str(tmp_path / "store")
    texts = _texts(24, seed=1)
    E.TokenStore.build(texts, max_len=8, chunk=8, backing="mmap",
                       cache_dir=cache, fingerprint="full")
    manifest = os.path.join(cache, "chunk_hashes.json")
    assert os.path.exists(manifest)
    texts[0] = [9, 9, 9]                            # edge change: fast sees it
    st = E.TokenStore.build(texts, max_len=8, chunk=8, backing="mmap",
                            cache_dir=cache, fingerprint="fast")
    assert st.rebuilt_chunks == st.n_chunks
    assert not os.path.exists(manifest)


def test_geometry_change_forces_full_rebuild(tmp_path):
    cache = str(tmp_path / "store")
    texts = _texts(32, seed=2)
    E.TokenStore.build(texts, max_len=8, chunk=8, backing="mmap",
                       cache_dir=cache, fingerprint="full")
    st = E.TokenStore.build(texts, max_len=8, chunk=16, backing="mmap",
                            cache_dir=cache, fingerprint="full")
    assert not st.reused and st.rebuilt_chunks == st.n_chunks == 2


# ---------------------------------------------------------------------------
# CLI: registry-validated flags
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("flag,value,kind", [
    ("--engine", "streaminge", "engine"), ("--impl", "cuda", "impl"),
    ("--mode", "rarank", "mode"), ("--sampler", "bm25", "sampler"),
])
def test_cli_rejects_unknown_component_names_at_parse_time(capsys, flag,
                                                           value, kind):
    """Unknown component names fail through the registry immediately after
    parsing — before any corpus IO (the paths here do not exist) — with
    the registered alternatives listed."""
    from repro.core.cli import main
    with pytest.raises(SystemExit) as ei:
        main(["--query_file", "q.jsonl", "--candidate_dir", "c",
              "--ckpts_dir", "ck", "--qrel_file", "qr.txt", flag, value])
    assert ei.value.code == 2                      # usage error
    err = capsys.readouterr().err
    assert f"unknown {kind} '{value}'" in err


def test_cli_rejects_run_sampler_without_run_file(tmp_path, capsys):
    """--sampler run_topk (or rerank mode) without --run_file must error at
    parse time, not AttributeError after the corpus loaded (paths here do
    not exist, so reaching IO would raise something else)."""
    from repro.core.cli import main
    base = ["--query_file", "q.jsonl", "--candidate_dir",
            str(tmp_path / "nope"), "--ckpts_dir", str(tmp_path / "ck"),
            "--qrel_file", str(tmp_path / "none.txt")]
    for extra in (["--sampler", "run_topk"], ["--mode", "rerank"]):
        with pytest.raises(SystemExit) as ei:
            main(base + extra)
        assert ei.value.code == 2
        assert "run_file" in capsys.readouterr().err
    # samplers whose --depth needs no run file pass the parse-time checks
    # (the nonexistent query file is the first thing touched after them)
    with pytest.raises(FileNotFoundError):
        main(base + ["--sampler", "random", "--depth", "50"])


def test_cli_rejects_alien_task_metric_before_any_io(tmp_path, capsys):
    """A composite --early_stop_metric naming a task this run does not
    validate must fail at parse time, before any corpus file is touched
    (the paths here do not exist)."""
    from repro.core.cli import main
    with pytest.raises(SystemExit) as ei:
        main(["--query_file", "q.jsonl", "--candidate_dir",
              str(tmp_path / "nope"), "--ckpts_dir", str(tmp_path / "ck"),
              "--qrel_file", str(tmp_path / "none.txt"),
              "--metrics", "MRR@10", "--early_stop",
              "--early_stop_metric", "0.5*dev:MRR@10 + 0.5*MRR@10"])
    assert ei.value.code == 2
    assert "dev:MRR@10" in capsys.readouterr().err
