"""Serve<->validate bit-parity: serving numbers ARE validation numbers.

Kim et al. 2022's training-inference gap, as an executable claim: for a
fixed checkpoint, the QueryService's answers (doc ids + scores + tie-break
order) must be bit-identical to what ``ValidationSuite.validate_params``
scored — across every ``score_dtype`` (f32/bf16/int8), sharded and
single-device, through the real micro-batching request path with its
fixed-shape padding and arbitrary batch boundaries.
"""

import concurrent.futures

import numpy as np
import pytest

from tests.hypothesis_compat import given, settings, st

from benchmarks.common import toy_spec, train_toy_dr
from repro.core import metrics as metrics_lib
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.data import corpus as corpus_lib
from repro.distributed import compat
from repro.serve import IndexBuilder, QueryService, ServeConfig

K = 10


@pytest.fixture(scope="module")
def setup():
    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=240,
                                                n_queries=12)
    spec = toy_spec(ds.vocab)
    _, snaps = train_toy_dr(ds, spec, steps=20, snapshot_every=20)
    return ds, spec, snaps[-1][1]


def _suite(ds, spec, *, score_dtype="f32", mesh=None, impl="xla"):
    vcfg = ValidationConfig(metrics=("MRR@10",), k=K, batch_size=32,
                            score_dtype=score_dtype, mesh=mesh, impl=impl)
    return ValidationSuite(spec, [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels)], vcfg)


def _serve_run(ds, spec, params, *, score_dtype="f32", mesh=None,
               impl="xla", max_batch=5, threaded=True, step=7):
    """Answer every query through the REAL request path: a started
    micro-batcher with concurrent submits (arbitrary batch packing), or
    the synchronous ``answer`` path when ``threaded`` is False."""
    cfg = ServeConfig(k=K, score_dtype=score_dtype, mesh=mesh, impl=impl,
                      batch_size=32, max_batch=max_batch, flush_ms=2.0)
    builder = IndexBuilder(spec, ds.corpus, cfg)
    service = QueryService(spec, k=K, max_batch=max_batch, flush_ms=2.0)
    service.install(builder.build(params, step))
    items = [(q, ds.queries[q]) for q in ds.queries]
    if not threaded:
        resp = service.answer(items)
    else:
        service.start()
        try:
            with concurrent.futures.ThreadPoolExecutor(4) as pool:
                resp = list(pool.map(
                    lambda it: service.submit(it[0], it[1], timeout=30),
                    items))
        finally:
            service.stop()
    assert all(r.step == step for r in resp), \
        "every response must attribute the installed checkpoint"
    return ({r.qid: r.doc_ids for r in resp},
            {r.qid: r.scores for r in resp})


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["single_device", "sharded"])
@pytest.mark.parametrize("score_dtype", ["f32", "bf16", "int8"])
def test_serve_matches_validator_bitwise(setup, score_dtype, sharded):
    """The acceptance matrix: ids + scores + tie order, bit-identical,
    for score_dtype x sharded/single-device.  The sharded leg uses a
    1-device mesh — the full shard_map/hierarchical-merge machinery runs
    deterministically (multi-device is the slow-tier subprocess test)."""
    ds, spec, params = setup
    mesh = compat.make_mesh((1,), ("data",)) if sharded else None
    suite = _suite(ds, spec, score_dtype=score_dtype, mesh=mesh)
    val_run, val_scores, _ = suite.engine("default").run(params)
    srv_run, srv_scores = _serve_run(ds, spec, params,
                                     score_dtype=score_dtype, mesh=mesh)
    assert srv_run == val_run          # ids, in rank (tie-broken) order
    assert srv_scores == val_scores    # float-exact scores

    # close the loop through validate_params: metrics computed from the
    # served run equal the suite's ledger-bound metrics exactly
    suite_metrics = suite.validate_params(params, step=7,
                                          write_runs=False).metrics
    served_metrics = metrics_lib.compute_metrics(srv_run, ds.qrels,
                                                 ["MRR@10"])
    assert served_metrics["MRR@10"] == suite_metrics["MRR@10"]


def test_serve_matches_validator_pallas(setup):
    """The pallas kernel path: serve's topk_mips dispatch against the
    validator's pallas streaming engine, bit-identical at f32."""
    ds, spec, params = setup
    suite = _suite(ds, spec, impl="pallas")
    val_run, val_scores, _ = suite.engine("default").run(params)
    srv_run, srv_scores = _serve_run(ds, spec, params, impl="pallas")
    assert srv_run == val_run
    assert srv_scores == val_scores


def test_tie_break_parity_duplicate_docs(setup):
    """Exact score ties (duplicated passages) must resolve identically on
    both paths — the rank_candidates stable-tie-break discipline extended
    to serving: identical score sets imply identical runs, not just
    identical up to tie order."""
    ds, spec, params = setup
    dup = dict(ds.corpus)
    base = list(ds.corpus.items())[:20]
    for did, toks in base:
        dup[f"{did}__dup"] = list(toks)   # bitwise-equal duplicate rows
    import dataclasses
    ds_dup = dataclasses.replace(ds, corpus=dup)
    suite = _suite(ds_dup, spec)
    val_run, val_scores, _ = suite.engine("default").run(params)
    srv_run, srv_scores = _serve_run(ds_dup, spec, params)
    assert srv_run == val_run
    assert srv_scores == val_scores
    # the ties actually engaged: some query surfaced a duplicated doc
    assert any(d.endswith("__dup") or f"{d}__dup" in dup
               for r in val_run.values() for d in r)


def test_micro_batch_packing_invariance(setup):
    """A query's answer must not depend on where it lands in a micro-batch
    (row-independent encoders + fixed-shape padding): alone, in a full
    batch, and through the threaded batcher all agree bitwise."""
    ds, spec, params = setup
    runs = []
    for max_batch, threaded in ((1, False), (len(ds.queries), False),
                                (3, True)):
        runs.append(_serve_run(ds, spec, params, max_batch=max_batch,
                               threaded=threaded))
    assert runs[0] == runs[1] == runs[2]


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=1, max_value=10_000))
def test_serve_parity_property(seed):
    """Property form of the acceptance claim: any synthetic corpus, any
    checkpoint — serve == validate, bitwise (f32; the dtype matrix is the
    parametrized test above)."""
    ds = corpus_lib.synthetic_retrieval_dataset(seed, n_passages=120,
                                                n_queries=6)
    spec = toy_spec(ds.vocab)
    _, snaps = train_toy_dr(ds, spec, steps=10, snapshot_every=10)
    params = snaps[-1][1]
    suite = _suite(ds, spec)
    val_run, val_scores, _ = suite.engine("default").run(params)
    srv_run, srv_scores = _serve_run(ds, spec, params, threaded=False)
    assert srv_run == val_run
    assert srv_scores == val_scores


@pytest.mark.parametrize("seed", [11, 29, 47])
def test_serve_parity_seeded(seed):
    """Seeded fallback for environments without hypothesis: the same
    property, pinned."""
    ds = corpus_lib.synthetic_retrieval_dataset(seed, n_passages=120,
                                                n_queries=6)
    spec = toy_spec(ds.vocab)
    _, snaps = train_toy_dr(ds, spec, steps=10, snapshot_every=10)
    params = snaps[-1][1]
    suite = _suite(ds, spec)
    val_run, val_scores, _ = suite.engine("default").run(params)
    srv_run, srv_scores = _serve_run(ds, spec, params, threaded=False)
    assert srv_run == val_run
    assert srv_scores == val_scores


@pytest.mark.slow
def test_serve_parity_multidevice_padded():
    """8-device sharded serving with a corpus NOT divisible by the mesh:
    the zero-pad + over-request + host-filter path must still match the
    single-device answer exactly (tie-free corpus).  Runs in a subprocess
    with XLA-forced devices, like tests/test_distributed.py."""
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import numpy as np
        from benchmarks.common import toy_spec, train_toy_dr
        from repro.data import corpus as corpus_lib
        from repro.distributed import compat
        from repro.serve import IndexBuilder, ServeConfig
        from repro.core.encoder import jitted_encoder
        from repro.data.corpus import pad_batch
        import jax.numpy as jnp

        ds = corpus_lib.synthetic_retrieval_dataset(3, n_passages=205,
                                                    n_queries=8)
        spec = toy_spec(ds.vocab)
        _, snaps = train_toy_dr(ds, spec, steps=10, snapshot_every=10)
        params = snaps[-1][1]
        mesh = compat.make_mesh((8,), ("data",))
        assert 205 % 8 != 0
        qids = list(ds.queries)
        toks, mask = pad_batch([ds.queries[q] for q in qids],
                               spec.q_max_len)
        q_emb = jitted_encoder(spec.encode_query)(
            params, jnp.asarray(toks), jnp.asarray(mask))
        runs = []
        for m in (None, mesh):
            idx = IndexBuilder(spec, ds.corpus,
                               ServeConfig(k=10, mesh=m, batch_size=32)
                               ).build(params, 1)
            assert (idx.n_pad > 0) == (m is not None)
            runs.append(idx.search_run(qids, q_emb, k=10))
        assert runs[0] == runs[1], "padded sharded run diverged"
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
