"""Trainer (checkpoint/restart, async save, grad-accum) + optimizers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig, make_train_step

# ---------------------------------------------------------------------------
# A 2-parameter quadratic problem with deterministic batches.
# ---------------------------------------------------------------------------

TARGET = jnp.asarray([3.0, -2.0])


def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    loss = jnp.mean(jnp.square(pred - batch["y"]))
    return loss, {"mse": loss}


def batch_for(step: int, n=16):
    rng = np.random.default_rng(step)
    x = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    return {"x": x, "y": x @ TARGET}


def init_params(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(2,)), jnp.float32)}


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_opt", [
    lambda: optim.adamw(5e-2, weight_decay=0.0),
    lambda: optim.adafactor(5e-1),
    lambda: optim.compressed(optim.adamw(5e-2, weight_decay=0.0)),
])
def test_optimizers_converge(make_opt):
    opt = make_opt()
    params = init_params()
    state = opt.init(params)
    step = jax.jit(make_train_step(loss_fn, opt))
    for i in range(300):
        params, state, m = step(params, state, batch_for(i))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(TARGET),
                               atol=0.15)


def test_adamw_matches_reference_update():
    """One AdamW step against a hand-rolled numpy reference."""
    opt = optim.adamw(0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      max_grad_norm=None)
    params = {"w": jnp.asarray([1.0, 2.0])}
    state = opt.init(params)
    grads = {"w": jnp.asarray([0.5, -1.0])}
    new_p, _ = opt.update(grads, state, params)
    g = np.asarray([0.5, -1.0])
    m = 0.1 * g / (1 - 0.9)
    v = 0.001 * g ** 2 / (1 - 0.999)
    exp = np.asarray([1.0, 2.0]) - 0.1 * m / (np.sqrt(v) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), exp, rtol=1e-5)


def test_grad_clipping():
    g = {"a": jnp.asarray([300.0, 400.0])}        # norm 500
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(500.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]),
                               [0.6, 0.8], rtol=1e-5)


def test_int8_compression_error_feedback():
    """Quantize->dequantize error carried forward, not lost."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(100,)), jnp.float32)
    q, scale = optim.quantize_int8(x)
    assert q.dtype == jnp.int8
    deq = optim.dequantize_int8(q, scale)
    err = np.abs(np.asarray(deq - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6   # round-to-nearest bound


def test_warmup_cosine_schedule():
    sched = optim.warmup_cosine(1.0, 10, 100, final_frac=0.1)
    assert float(sched(0)) == pytest.approx(0.0)
    assert float(sched(10)) == pytest.approx(1.0, abs=0.01)
    assert float(sched(100)) == pytest.approx(0.1, abs=0.01)
    assert float(sched(55)) < 1.0


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------

def test_trainer_runs_and_checkpoints(tmp_path):
    cfg = TrainerConfig(total_steps=25, ckpt_every=10,
                        ckpt_dir=str(tmp_path / "ck"), log_every=5)
    tr = Trainer(cfg, loss_fn, optim.adamw(5e-2), init_params(), batch_for)
    hist = tr.run()
    assert tr.step == 25
    assert ckpt.list_steps(cfg.ckpt_dir) == [10, 20, 25]
    assert hist[-1][1]["loss"] < hist[0][1]["loss"]


def test_trainer_restart_resumes_exactly(tmp_path):
    """Kill-and-restart must produce bit-identical params to an
    uninterrupted run (params + opt state + data cursor restored)."""
    ckdir = str(tmp_path / "ck")
    cfg = TrainerConfig(total_steps=30, ckpt_every=10, ckpt_dir=ckdir,
                        async_save=False)
    # uninterrupted reference
    ref = Trainer(TrainerConfig(total_steps=30, ckpt_every=10,
                                ckpt_dir=str(tmp_path / "ref"),
                                async_save=False),
                  loss_fn, optim.adamw(5e-2), init_params(), batch_for)
    ref.run()
    # interrupted: run to 30 but simulate crash by constructing a trainer
    # that stops at 20 (fresh process restores from step-20 checkpoint)
    t1 = Trainer(TrainerConfig(total_steps=20, ckpt_every=10, ckpt_dir=ckdir,
                               async_save=False),
                 loss_fn, optim.adamw(5e-2), init_params(), batch_for)
    t1.run()
    t2 = Trainer(cfg, loss_fn, optim.adamw(5e-2), init_params(seed=999),
                 batch_for)                      # wrong init: must be ignored
    assert t2.step == 20                          # resumed, not restarted
    t2.run()
    np.testing.assert_array_equal(np.asarray(t2.params["w"]),
                                  np.asarray(ref.params["w"]))


def test_grad_accum_matches_large_batch():
    """grad_accum=4 over a 64-batch == single 64-batch step (linear model)."""
    opt = optim.adamw(1e-2, max_grad_norm=None)
    params = init_params()
    batch = batch_for(0, n=64)
    s1 = jax.jit(make_train_step(loss_fn, opt))
    s4 = jax.jit(make_train_step(loss_fn, opt, grad_accum=4))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=1e-5)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
