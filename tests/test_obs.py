"""Checkpoint-lifecycle telemetry (repro.obs): metrics registry, span
tracer, Chrome export, the bounded validator fault ring, BudgetPolicy on
shared registry instruments, and the 2-worker fleet trace with exactly
one ``scored`` span per (step, task)."""

import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import (CKPT_TO_VERDICT_METRIC, AsyncValidator,
                                  ErrorRing, ValidationLedger,
                                  ValidatorWorker)
from repro.core.watcher import (CHECKPOINT_CADENCE_METRIC,
                                DISCOVERY_LAG_METRIC,
                                VALIDATION_LATENCY_METRIC, BudgetPolicy,
                                CheckpointWatcher)
from repro.core.workqueue import WorkQueue
from repro.data import corpus as synthetic_ds
from repro.models.biencoder import EncoderSpec
from repro.obs import (LIFECYCLE_STAGES, Counter, Ewma, Gauge, Histogram,
                       MetricsRegistry, SpanTracer, Telemetry, read_trace)
from repro.obs import export as obs_export

DIM = 8
VOCAB = 97


def _toy_encode(params, tokens, mask):
    emb = jnp.take(params["table"], tokens, axis=0)
    m = mask.astype(emb.dtype)[..., None]
    v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
    return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def toy_spec():
    return EncoderSpec(
        name="toy", dim=DIM, encode_query=_toy_encode,
        encode_passage=_toy_encode,
        init=lambda rng: {"table": jax.random.normal(rng, (VOCAB, DIM))},
        q_max_len=8, p_max_len=12)


@pytest.fixture(scope="module")
def ds():
    return synthetic_ds.synthetic_retrieval_dataset(7, n_passages=40,
                                                    n_queries=8, vocab=VOCAB)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    c = Counter("c")
    c.inc()
    c.inc(3)
    assert c.value == 4
    assert c.snapshot() == {"type": "counter", "value": 4}
    g = Gauge("g")
    assert g.value is None
    g.set(2.5)
    assert g.snapshot() == {"type": "gauge", "value": 2.5}


def test_ewma_matches_canonical_rule():
    e = Ewma("e", smooth=0.5)
    assert e.value is None
    e.update(4.0)
    assert e.value == 4.0               # first sample adopted exactly
    e.update(8.0)
    assert e.value == 0.5 * 4.0 + 0.5 * 8.0
    assert e.count == 2


def test_histogram_percentiles_nearest_rank():
    h = Histogram("h")
    for v in [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]:
        h.observe(v)
    assert h.count == 10
    assert h.mean == pytest.approx(5.5)
    assert h.percentile(50) == 5.0      # nearest-rank: ceil(0.5*10)=5th
    assert h.percentile(99) == 10.0
    assert h.vmin == 1.0 and h.vmax == 10.0
    assert Histogram("empty").percentile(50) is None


def test_histogram_reservoir_is_bounded():
    h = Histogram("h", maxlen=4)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100               # totals keep the full history
    assert h.percentile(50) == 97.0     # percentiles over the recent window


def test_registry_shares_instruments_and_rejects_type_conflicts():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    assert reg.get("x").value == 0
    assert reg.get("never-created") is None
    assert reg.names() == ["x"]


def test_registry_snapshot_dump_render(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a.count").inc(2)
    reg.histogram("b.lat_s").observe(0.5)
    reg.ewma("c.ema").update(1.0)
    out = tmp_path / "metrics.json"
    reg.dump(str(out))
    snap = json.loads(out.read_text())
    assert snap["a.count"] == {"type": "counter", "value": 2}
    assert snap["b.lat_s"]["count"] == 1
    table = reg.render()
    for name in ("metric", "a.count", "b.lat_s", "c.ema"):
        assert name in table


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------

def test_span_nesting_parent_child(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    tr = SpanTracer(path, process="p0", attrs={"worker_id": "w0"})
    with tr.span("scored", step=3, task="default") as outer:
        with tr.span("encoded", role="query") as inner:
            tr.event("published", step=3)
        tr.record("staged", time.monotonic() - 0.25, 0.25, n_batches=4)
    tr.flush()
    recs = {r["name"]: r for r in read_trace(path)}
    assert recs["scored"]["parent"] is None
    assert recs["encoded"]["parent"] == recs["scored"]["id"]
    assert recs["staged"]["parent"] == recs["scored"]["id"]
    assert recs["published"]["parent"] == recs["encoded"]["id"]
    assert recs["published"]["kind"] == "event"
    # spans carry monotonic intervals and flat attrs (defaults included)
    assert recs["scored"]["dur"] >= recs["encoded"]["dur"]
    assert all(r["worker_id"] == "w0" and r["process"] == "p0"
               for r in recs.values())
    assert recs["scored"]["step"] == 3
    assert outer.id != inner.id


def test_span_records_exception_and_propagates(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    tr = SpanTracer(path)
    with pytest.raises(ValueError):
        with tr.span("scored", step=1):
            raise ValueError("boom")
    tr.flush()
    (rec,) = read_trace(path)
    assert "ValueError" in rec["error"]


def test_tracer_buffers_until_flush(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    tr = SpanTracer(path, flush_every=1000)
    tr.event("produced", step=1)
    assert not os.path.exists(path)     # buffered, no I/O yet
    tr.flush()
    assert len(read_trace(path)) == 1
    tr.flush()                          # empty flush is a no-op
    assert len(read_trace(path)) == 1


def test_threads_do_not_adopt_each_others_spans(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    tr = SpanTracer(path)
    ready = threading.Event()
    release = threading.Event()

    def other():
        ready.wait(5)
        tr.event("discovered", step=9)
        release.set()

    t = threading.Thread(target=other)
    t.start()
    with tr.span("scored", step=1):
        ready.set()
        release.wait(5)
    t.join()
    tr.flush()
    recs = {r["name"]: r for r in read_trace(path)}
    # the event fired while `scored` was open on ANOTHER thread: no parent
    assert recs["discovered"]["parent"] is None


def test_disabled_telemetry_is_noop(tmp_path):
    tel = Telemetry(None)
    assert tel.tracer is None
    with tel.span("scored", step=1):    # nullcontext, reusable
        pass
    with tel.span("scored", step=2):
        pass
    tel.event("produced", step=1)
    tel.record("staged", 0.0, 1.0)
    tel.flush()
    assert os.listdir(tmp_path) == []   # wrote nothing anywhere
    tel.metrics.counter("still.works").inc()
    assert tel.metrics.get("still.works").value == 1


def test_mark_since_cross_stage_latency():
    tel = Telemetry(None)
    assert tel.since("discovered", 5) is None      # never marked
    tel.mark("discovered", 5)
    lag = tel.since("discovered", 5)
    assert lag is not None and lag >= 0.0
    assert tel.since("discovered", 5, pop=True) is not None
    assert tel.since("discovered", 5) is None      # popped


# ---------------------------------------------------------------------------
# Chrome export + stage summaries
# ---------------------------------------------------------------------------

def test_chrome_export_round_trip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    tr = SpanTracer(path, process="worker-0")
    with tr.span("scored", step=1, task="default"):
        tr.event("published", step=1)
    tr.flush()
    out = str(tmp_path / "chrome.json")
    doc = obs_export.write_chrome([path], out)
    loaded = json.loads(open(out).read())
    assert loaded == doc
    phases = [e["ph"] for e in loaded["traceEvents"]]
    assert "M" in phases and "X" in phases and "i" in phases
    meta = next(e for e in loaded["traceEvents"] if e["ph"] == "M")
    assert meta["args"]["name"] == "worker-0"
    span = next(e for e in loaded["traceEvents"] if e["ph"] == "X")
    assert span["name"] == "scored"
    assert span["cat"] == "lifecycle"
    assert span["dur"] >= 1.0                      # microseconds, floored
    assert span["args"]["step"] == 1
    assert span["args"]["task"] == "default"
    assert "span_id" in span["args"]
    inst = next(e for e in loaded["traceEvents"] if e["ph"] == "i")
    assert inst["name"] == "published" and inst["s"] == "t"
    assert inst["args"]["parent_id"] == span["args"]["span_id"]


def test_stage_summary_self_time_excludes_children():
    recs = [
        {"kind": "span", "name": "scored", "id": 1, "parent": None,
         "t0": 0.0, "dur": 1.0, "pid": 1, "_file": "f"},
        {"kind": "span", "name": "encoded", "id": 2, "parent": 1,
         "t0": 0.1, "dur": 0.4, "pid": 1, "_file": "f"},
        {"kind": "event", "name": "published", "id": 3, "parent": None,
         "t": 0.0, "pid": 1, "_file": "f"},
    ]
    summary = obs_export.stage_summary(recs)
    assert summary["scored"]["total_s"] == pytest.approx(1.0)
    assert summary["scored"]["self_s"] == pytest.approx(0.6)
    assert summary["encoded"]["self_s"] == pytest.approx(0.4)
    assert summary["published"]["count"] == 1
    assert summary["published"]["total_s"] == 0.0
    table = obs_export.breakdown_table(recs)
    lines = table.splitlines()
    # lifecycle order: published (event) before encoded before scored
    order = [ln.split()[0] for ln in lines[2:]]
    assert order == ["published", "encoded", "scored"]


def test_export_cli_merges_files(tmp_path, capsys):
    p0, p1 = str(tmp_path / "w0.jsonl"), str(tmp_path / "w1.jsonl")
    for i, p in enumerate((p0, p1)):
        tr = SpanTracer(p, process=f"worker-{i}")
        with tr.span("scored", step=i):
            pass
        tr.flush()
    out = str(tmp_path / "chrome.json")
    assert obs_export.main([p0, p1, "--chrome", out, "--summary"]) == 0
    printed = capsys.readouterr().out
    assert "scored" in printed
    doc = json.loads(open(out).read())
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M"}
    assert names == {"worker-0", "worker-1"}


# ---------------------------------------------------------------------------
# ErrorRing (bounded validator fault list)
# ---------------------------------------------------------------------------

def test_error_ring_caps_and_counts_drops():
    ring = ErrorRing(maxlen=3)
    for i in range(5):
        ring.append((i, f"e{i}"))
    assert len(ring) == 3
    assert ring.dropped == 2
    assert [e[0] for e in ring] == [2, 3, 4]       # newest kept
    assert ring[-1] == (4, "e4")
    assert ring[:2] == [(2, "e2"), (3, "e3")]
    assert bool(ring)
    c = Counter("validator.errors_dropped")
    ring.bind_counter(c)
    assert c.value == 2                             # pre-bind drops counted
    ring.append((5, "e5"))
    assert c.value == 3
    ring.clear()
    assert not ring and len(ring) == 0


def test_worker_error_ring_is_bounded(ds, tmp_path):
    vcfg = ValidationConfig(metrics=("MRR@10",), batch_size=8)
    suite = ValidationSuite(toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels)], vcfg)
    tel = Telemetry(None)
    w = ValidatorWorker(str(tmp_path), suite, telemetry=tel, max_errors=2)
    for i in range(4):
        w.errors.append((i, "x"))
    assert len(w.errors) == 2
    assert tel.metrics.get("validator.errors_dropped").value == 2


# ---------------------------------------------------------------------------
# BudgetPolicy on shared registry instruments
# ---------------------------------------------------------------------------

def test_budget_policy_feeds_shared_registry():
    reg = MetricsRegistry()
    pol = BudgetPolicy(smooth=0.5)
    pol.bind_metrics(reg)
    pol.observe_latency(4.0)
    pol.observe_cadence(1.0)
    lat = reg.get(VALIDATION_LATENCY_METRIC)
    cad = reg.get(CHECKPOINT_CADENCE_METRIC)
    assert lat.value == 4.0 and cad.value == 1.0
    pol.observe_latency(8.0)
    assert lat.value == 0.5 * 4.0 + 0.5 * 8.0      # policy's own smooth
    # an external reader sees exactly what the policy decides from
    assert pol.select([10])                         # stride floors at >=1


def test_budget_policy_rebind_carries_state_over():
    pol = BudgetPolicy(smooth=0.5)
    pol.observe_latency(4.0)                        # on the private registry
    reg = MetricsRegistry()
    pol.bind_metrics(reg)
    assert reg.get(VALIDATION_LATENCY_METRIC).value == 4.0
    assert reg.get(VALIDATION_LATENCY_METRIC).count == 1


def test_watcher_binds_policy_and_observes_discovery(tmp_path):
    root = str(tmp_path / "ckpts")
    tel = Telemetry(str(tmp_path / "trace.jsonl"))
    pol = BudgetPolicy()
    watcher = CheckpointWatcher(root, policy=pol, telemetry=tel)
    # the policy's instruments live on the shared registry now
    assert tel.metrics.get(CHECKPOINT_CADENCE_METRIC) is not None
    ckpt.save(root, 10, {"params": {"x": jnp.zeros(2)}})
    assert watcher.poll() == [10]
    tel.flush()
    recs = [r for r in read_trace(str(tmp_path / "trace.jsonl"))
            if r["name"] == "discovered"]
    assert len(recs) == 1 and recs[0]["step"] == 10
    lag_hist = tel.metrics.get(DISCOVERY_LAG_METRIC)
    assert lag_hist is not None and lag_hist.count == 1
    assert tel.since("discovered", 10) is not None  # verdict mark is set


# ---------------------------------------------------------------------------
# Solo validator end-to-end: spans + checkpoint-to-verdict latency
# ---------------------------------------------------------------------------

def test_solo_validator_traces_full_lifecycle(ds, tmp_path):
    spec = toy_spec()
    root = str(tmp_path / "ckpts")
    params = spec.init(jax.random.PRNGKey(0))
    ckpt.save(root, 5, {"params": params})
    trace = str(tmp_path / "trace.jsonl")
    tel = Telemetry(trace, attrs={"worker_id": "solo"})
    vcfg = ValidationConfig(metrics=("MRR@10",), batch_size=8)
    suite = ValidationSuite(spec, [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels)], vcfg)
    av = AsyncValidator(root, suite, telemetry=tel,
                        ledger_path=str(tmp_path / "ledger.jsonl"))
    assert av.validate_pending() == 1
    tel.flush()
    names = {r["name"] for r in read_trace(trace)}
    assert {"discovered", "store_build", "staged", "encoded", "scored",
            "recorded"} <= names
    hist = tel.metrics.get(CKPT_TO_VERDICT_METRIC)
    assert hist is not None and hist.count == 1
    assert hist.percentile(50) is not None
    # the suite config got the handle threaded through automatically
    assert vcfg.telemetry is tel


def test_disabled_telemetry_identical_ledger(ds, tmp_path):
    """Telemetry on/off writes identical ledger rows (modulo the wall-time
    timing fields, which vary run to run regardless) and telemetry-off
    writes no trace file — the observe-never-participate acceptance
    gate."""
    spec = toy_spec()
    params = spec.init(jax.random.PRNGKey(0))

    def run(workdir, tel):
        root = os.path.join(workdir, "ckpts")
        ckpt.save(root, 5, {"params": params})
        vcfg = ValidationConfig(metrics=("MRR@10",), batch_size=8)
        suite = ValidationSuite(spec, [
            ValidationTask("default", ds.corpus, ds.queries, ds.qrels)],
            vcfg)
        led = os.path.join(workdir, "ledger.jsonl")
        av = AsyncValidator(root, suite, telemetry=tel, ledger_path=led)
        assert av.validate_pending() == 1
        rows = [json.loads(ln) for ln in open(led)]
        for row in rows:
            row.pop("timings", None)
        return rows

    off = run(str(tmp_path / "off"), None)
    on_dir = str(tmp_path / "on")
    on = run(on_dir, Telemetry(os.path.join(on_dir, "trace.jsonl")))
    assert off == on
    assert not any(f.endswith("trace.jsonl")
                   for f in os.listdir(str(tmp_path / "off")))


# ---------------------------------------------------------------------------
# 2-worker fleet: one `scored` span per (step, task), attributed
# ---------------------------------------------------------------------------

def test_two_worker_fleet_trace_attribution(ds, tmp_path):
    spec = toy_spec()
    root = str(tmp_path / "ckpts")
    for step in (1, 2):
        ckpt.save(root, step,
                  {"params": spec.init(jax.random.PRNGKey(step))})
    ledger_path = str(tmp_path / "ledger.jsonl")

    def make_worker(wid):
        trace = str(tmp_path / f"{wid}.jsonl")
        tel = Telemetry(trace, process=wid, attrs={"worker_id": wid})
        vcfg = ValidationConfig(metrics=("MRR@10",), batch_size=8,
                                telemetry=tel)
        suite = ValidationSuite(spec, [
            ValidationTask("a", ds.corpus, ds.queries, ds.qrels),
            ValidationTask("b", ds.corpus, ds.queries, ds.qrels)], vcfg)
        queue = WorkQueue(ledger_path, wid, lease_ttl=16,
                          capabilities={"mesh_size": jax.device_count()},
                          telemetry=tel)
        worker = ValidatorWorker(
            root, suite,
            ledger=ValidationLedger(ledger_path,
                                    expected_tasks=suite.task_names,
                                    telemetry=tel),
            queue=queue, worker_id=wid, telemetry=tel)
        return worker, suite, tel, trace

    w0, suite0, tel0, trace0 = make_worker("w0")
    w1, _, tel1, trace1 = make_worker("w1")
    for step in (1, 2):
        w0.queue.publish(suite0.plan_units(step))
    # alternate claim rounds until the 4-unit backlog drains
    for _ in range(16):
        if len(w0.completed) + len(w1.completed) == 4:
            break
        w0.run_once()
        w1.run_once()
    assert len(w0.completed) + len(w1.completed) == 4
    assert w0.completed and w1.completed            # both did real work
    w0.queue.refresh()      # mirror the tail events into the counters
    w1.queue.refresh()
    tel0.flush()
    tel1.flush()

    records = obs_export.load_traces([trace0, trace1])
    scored = [r for r in records if r["name"] == "scored"
              and r["kind"] == "span"]
    # exactly one scored span per (step, task) across the whole fleet
    assert sorted((r["step"], r["task"]) for r in scored) == \
        [(1, "a"), (1, "b"), (2, "a"), (2, "b")]
    # worker attribution matches the ledger rows' worker_id stamps
    rows = ValidationLedger(ledger_path).rows()
    by_unit = {(row["step"], row["task"]): row["worker_id"]
               for row in rows if "task" in row and "worker_id" in row}
    for r in scored:
        assert r["worker_id"] == by_unit[(r["step"], r["task"])]
        assert r["process"] == r["worker_id"]
    # the fleet protocol stages show up too, on the right workers
    names = {r["name"] for r in records}
    assert {"published", "claimed", "store_build", "scored",
            "recorded"} <= names
    claimed = [r for r in records if r["name"] == "claimed"]
    assert {(r["step"], r["task"]) for r in claimed} == \
        {(1, "a"), (1, "b"), (2, "a"), (2, "b")}
    for r in claimed:                   # a worker only logs claims it WON
        assert r["worker_id"] == by_unit[(r["step"], r["task"])]
    # mirrored queue counters: every handle folds the whole shared ledger,
    # so each worker's registry shows the GLOBAL publish/completion counts
    for tel in (tel0, tel1):
        assert tel.metrics.get("fleet.publish").value == 4
        assert tel.metrics.get("fleet.complete").value == 4
    claims = sum(t.metrics.get("fleet.claim").value for t in (tel0, tel1))
    assert claims >= 4
    # ckpt-to-verdict latency observed on every completed unit's worker
    total_verdicts = sum(
        t.metrics.get(CKPT_TO_VERDICT_METRIC).count
        for t in (tel0, tel1) if t.metrics.get(CKPT_TO_VERDICT_METRIC))
    assert total_verdicts == 4
    # merged Chrome export covers both worker tracks
    out = str(tmp_path / "fleet.json")
    doc = obs_export.write_chrome([trace0, trace1], out)
    tracks = {e["args"]["name"] for e in doc["traceEvents"]
              if e["ph"] == "M"}
    assert tracks == {"w0", "w1"}


def test_lifecycle_vocabulary_is_stable():
    assert LIFECYCLE_STAGES == (
        "produced", "snapshotted", "discovered", "published", "claimed",
        "store_build", "staged", "encoded", "scored", "recorded",
        "selected", "promoted", "served")


def test_obs_report_prints_verdict_percentiles(capsys):
    import argparse

    from repro.core import cli
    tel = Telemetry(None)
    for v in (0.1, 0.2, 0.3):
        tel.metrics.histogram(CKPT_TO_VERDICT_METRIC).observe(v)
    args = argparse.Namespace(obs_report=True, obs_metrics=None)
    cli._obs_finish(args, tel)
    out = capsys.readouterr().out
    assert "checkpoint-to-verdict" in out
    assert "p50=" in out and "p99=" in out
    assert CKPT_TO_VERDICT_METRIC in out
