"""Checkpoint system: two-phase commit, async save, GC protection, restore."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 9, (2,)), jnp.int32),
                  "d": jnp.asarray(rng.normal(size=(5,)), jnp.bfloat16)}}


def test_save_restore_roundtrip(tmp_path):
    root = str(tmp_path / "ck")
    tree = _tree()
    ckpt.save(root, 10, tree, extra={"step": 10, "note": "x"})
    restored, extra = ckpt.restore(root, 10)
    assert extra["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_uncommitted_checkpoint_invisible(tmp_path):
    """A directory without the COMMIT marker must never be listed — the
    torn-read race the paper's directory polling glosses over."""
    root = str(tmp_path / "ck")
    ckpt.save(root, 1, _tree())
    ckpt.save(root, 2, _tree())
    os.remove(os.path.join(root, "step_0000000002", ckpt.COMMIT_MARKER))
    assert ckpt.list_steps(root) == [1]
    with pytest.raises(FileNotFoundError):
        ckpt.restore(root, 2)
    assert ckpt.latest_step(root) == 1


def test_idempotent_resave(tmp_path):
    """Restart replay: re-saving the same step must not corrupt."""
    root = str(tmp_path / "ck")
    ckpt.save(root, 5, _tree(0))
    ckpt.save(root, 5, _tree(1))           # replay with different values
    restored, _ = ckpt.restore(root, 5)
    np.testing.assert_array_equal(np.asarray(restored["a"], np.float32),
                                  np.asarray(_tree(1)["a"], np.float32))


def test_gc_respects_protection(tmp_path):
    root = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(root, s, _tree(s))
    deleted = ckpt.gc_checkpoints(root, keep_last=2, protect={2})
    assert 2 not in deleted
    assert ckpt.list_steps(root) == [2, 4, 5]


def test_async_saver_overlap_and_error_surfacing(tmp_path):
    root = str(tmp_path / "ck")
    saver = ckpt.AsyncSaver()
    saver.save(root, 1, _tree())
    saver.save(root, 2, _tree())           # waits for #1 internally
    saver.wait()
    assert ckpt.list_steps(root) == [1, 2]
    # an invalid path error must surface on next wait, not be swallowed
    saver.save("/proc/definitely/not/writable", 3, _tree())
    with pytest.raises(BaseException):
        saver.wait()


def test_restore_with_shardings_placement(tmp_path):
    root = str(tmp_path / "ck")
    tree = _tree()
    ckpt.save(root, 1, tree)
    dev = jax.devices()[0]
    sh = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    restored, _ = ckpt.restore(root, 1, shardings=sh)
    for leaf in jax.tree_util.tree_leaves(restored):
        assert leaf.devices() == {dev}


def test_concurrent_reader_never_sees_torn_state(tmp_path):
    """Reader thread polling during many saves only ever observes committed
    checkpoints (two-phase commit integration)."""
    root = str(tmp_path / "ck")
    seen, errors = [], []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            for s in ckpt.list_steps(root):
                try:
                    t, _ = ckpt.restore(root, s)
                    jax.tree_util.tree_leaves(t)
                except Exception as e:       # torn read -> bug
                    errors.append((s, e))
            seen.extend(ckpt.list_steps(root))

    th = threading.Thread(target=reader)
    th.start()
    for s in range(1, 15):
        ckpt.save(root, s, _tree(s))
    stop.set()
    th.join()
    assert not errors
