"""Render the §Dry-run/§Roofline sections of EXPERIMENTS.md from the
per-cell JSON records in experiments/dryrun/.

    PYTHONPATH=src python -m benchmarks.render_experiments
"""

from __future__ import annotations

import glob
import json
import os

OUT = "experiments/dryrun"
PEAK, HBM, ICI = 197e12, 819e9, 50e9

_MOVE = {
    "compute": ("raise per-device work or cut remat recompute (useful_frac "
                "{uf:.2f}); MXU-aligned tile shapes"),
    "memory": ("cut HBM round-trips: bf16 end-to-end, fuse boundary "
               "copies/transposes, shard the replicated activation dims"),
    "collective": ("reduce wire bytes: resident weights, hierarchical "
                   "merges, overlap collectives with compute"),
}


def load(tag="baseline"):
    recs = {}
    for p in sorted(glob.glob(os.path.join(OUT, f"*__{tag}.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("ok"):
            recs[(r["arch"], r["shape"])] = r
    return recs


def table_rows(recs):
    lines = ["| arch | shape | bound | comp_ms | mem_ms | memraw_ms | "
             "coll_ms | GiB/dev | GiB/dev@512 | useful | roofline |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s), r in sorted(recs.items()):
        ro = r["roofline"]
        mp = r.get("memory_multipod_per_device")
        lines.append(
            f"| {a} | {s} | {ro['bottleneck']} | {ro['compute_s']*1e3:.2f} "
            f"| {ro['memory_s']*1e3:.2f} | {ro.get('memory_raw_s',0)*1e3:.2f} "
            f"| {ro['collective_s']*1e3:.2f} "
            f"| {r['memory']['per_device_total']/2**30:.2f} "
            f"| {mp/2**30:.2f} " if mp else "| - "
        ) if False else lines.append(
            f"| {a} | {s} | {ro['bottleneck']} | {ro['compute_s']*1e3:.2f} "
            f"| {ro['memory_s']*1e3:.2f} "
            f"| {ro.get('memory_raw_s',0)*1e3:.2f} "
            f"| {ro['collective_s']*1e3:.2f} "
            f"| {r['memory']['per_device_total']/2**30:.2f} "
            f"| {(mp/2**30 if mp else 0):.2f} "
            f"| {ro['useful_flops_frac']:.2f} | {ro['roofline_frac']:.3f} |")
    return "\n".join(lines)


def per_cell_notes(recs):
    lines = ["### Per-cell §Roofline records", ""]
    for (a, s), r in sorted(recs.items()):
        ro = r["roofline"]
        m = r["meta"]
        move = _MOVE[ro["bottleneck"]].format(uf=ro["useful_flops_frac"])
        lines.append(
            f"* **{a}/{s}** — compute {ro['compute_s']:.4f}s / memory "
            f"{ro['memory_s']:.4f}s / collective {ro['collective_s']:.4f}s "
            f"-> **{ro['bottleneck']}-bound**. MODEL_FLOPS "
            f"{m['model_flops']:.3e} (params {m.get('params',0):.3e}, "
            f"active {m.get('active_params',0):.3e}); "
            f"MODEL_FLOPS/HLO_FLOPs = {ro['useful_flops_frac']:.2f}. "
            f"To move the dominant term: {move}.")
    return "\n".join(lines)


def analysis_text(recs):
    by_bound = {}
    for key, r in recs.items():
        by_bound.setdefault(r["roofline"]["bottleneck"], []).append(key)
    n = len(recs)
    fits = sum(1 for r in recs.values()
               if r["memory"]["per_device_total"] < 16 * 2**30)
    fits512 = sum(1 for r in recs.values()
                  if r.get("memory_multipod_per_device", 1e30) < 16 * 2**30)
    best = max(recs.items(), key=lambda kv: kv[1]["roofline"]["roofline_frac"])
    lines = [
        f"Across {n} baseline cells: "
        + ", ".join(f"{len(v)} {k}-bound" for k, v in sorted(by_bound.items()))
        + f". {fits}/{n} fit a 16 GiB HBM budget on the single pod; "
        f"{fits512}/{n} on the 512-chip multi-pod mesh (DP widening halves "
        "batch-linear buffers).",
        "",
        f"Best baseline roofline fraction: **{best[0][0]}/{best[0][1]}** at "
        f"{best[1]['roofline']['roofline_frac']:.3f} — dense-transformer "
        "training is the closest to the compute roofline, as expected: its "
        "arithmetic intensity (6 x params x tokens over params+activations "
        "traffic) is the highest in the pool.",
        "",
        "Structural findings:",
        "* **Training cells** are memory-term dominated on this metric; the "
        "biggest single contributor is remat recompute + the layer-boundary "
        "residual stream (mitigated by sequence parallelism, auto-enabled "
        "for the large archs).",
        "* **Decode cells** are intrinsically HBM-bound (one token against "
        "the full cache+weights per step; arithmetic intensity ~1); their "
        "collective term is layout-dependent (see §Perf iter b).",
        "* **GNN/recsys cells** are collective-bound: gather/segment-sum "
        "message passing and row-sharded embedding lookups place per-step "
        "all-to-all-like traffic on the wire that small MLP compute never "
        "amortizes. long-term fix: locality-aware partitioning (METIS-style "
        "edge cuts) so most messages stay on-device.",
        "* `long_500k` decode cells run at O(T) per emitted token with the "
        "cache sequence-sharded over the whole mesh — all five LM archs "
        "compile and fit (DESIGN.md §2.4 records the decode-only scope).",
    ]
    return "\n".join(lines)


def main():
    recs = load()
    md = open("EXPERIMENTS.md").read()
    md = md.replace("<!-- ROOFLINE_TABLE -->", table_rows(recs))
    md = md.replace("<!-- PER_CELL_NOTES -->", per_cell_notes(recs))
    md = md.replace("<!-- ROOFLINE_ANALYSIS -->", analysis_text(recs))
    open("EXPERIMENTS.md", "w").write(md)
    print(f"rendered {len(recs)} cells into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
