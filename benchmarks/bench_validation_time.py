"""Paper Figure 2 (right): per-checkpoint validation time vs subset depth.

The paper: full corpus ~2 h, top-1000 ~1 h, top-100 ~10 min on MS MARCO.
Here: wall-clock validation time across subset depths on the synthetic
corpus — the shape of the scaling (linear in encoded passages, dominated by
corpus encoding) is the reproduced artifact.

PR 9 turns the single wall-time number into a per-stage breakdown from the
lifecycle tracer (``repro.obs``): a traced run of the double-buffered
streaming config prints store_build/staged/encoded/scored/recorded
inclusive+self times, and GATES the staging idle-gap ratio (the fraction
of the scan loop spent waiting on host→device staging) below 10% — the
measured form of PR 2's "the device never idles on copies" claim.
"""

from __future__ import annotations

import os
import shutil
import tempfile

from benchmarks.common import toy_spec, train_toy_dr
from repro.core.samplers import FullCorpus, RunFileTopK
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import ValidationLedger
from repro.data import corpus as corpus_lib
from repro.obs import Telemetry
from repro.obs.export import breakdown_table, load_traces

# shared CI knob: loosen timing-sensitive gates on noisy runners
SLACK = float(os.environ.get("ASYNCVAL_BENCH_TIME_SLACK", "1.0"))
IDLE_GATE = 0.10 * SLACK


def _make_suite(ds, spec, sampler, baseline, *, engine: str,
                telemetry=None) -> ValidationSuite:
    vcfg = ValidationConfig(metrics=("MRR@10",), k=100, batch_size=128,
                            engine=engine, telemetry=telemetry)
    return ValidationSuite(spec, [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels,
                       sampler=sampler, baseline_run=baseline)], vcfg)


def run(corpus_size: int = 4000, n_queries: int = 60,
        depths=(5, 20, 50, 200), seed: int = 0, repeats: int = 3,
        engine: str = "streaming"):
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries)
    baseline = corpus_lib.lexical_baseline_run(ds, k=max(depths))
    spec = toy_spec(ds.vocab)
    params, _ = train_toy_dr(ds, spec, steps=50, seed=seed)

    rows = []
    samplers = [("full", FullCorpus())] + \
        [(f"top{d}", RunFileTopK(depth=d)) for d in depths]
    for name, sampler in samplers:
        suite = _make_suite(ds, spec, sampler, baseline, engine=engine)
        suite.validate_params(params)           # warm-up (jit compile)
        times, encode_times = [], []
        for r in range(repeats):
            res = suite.validate_params(params, step=r).tasks["default"]
            times.append(res.timings["total_s"])
            encode_times.append(res.timings["encode_corpus_s"])
        rows.append({"engine": engine, "subset": name,
                     "size": res.subset_size,
                     "total_s": min(times),
                     "encode_s": min(encode_times),
                     "mrr": res.metrics["MRR@10"]})
    return rows


def run_breakdown(corpus_size: int = 4000, n_queries: int = 60,
                  seed: int = 0, repeats: int = 3):
    """Trace full-corpus validations of the DOUBLE-BUFFERED streaming
    config (the ValidationConfig default: staging="double_buffered",
    depth 2); returns (trace records, post-warm-up staging idle ratios).

    The warm-up run stays in the trace — it is where store_build and the
    compile-heavy first spans live, so the printed table covers every
    stage — but the idle-gap GATE reads only the steady-state runs."""
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries)
    spec = toy_spec(ds.vocab)
    params, _ = train_toy_dr(ds, spec, steps=50, seed=seed)

    workdir = tempfile.mkdtemp(prefix="asyncval_obs_bench_")
    trace = os.path.join(workdir, "trace.jsonl")
    tel = Telemetry(trace, process="bench")
    suite = _make_suite(ds, spec, FullCorpus(), None,
                        engine="streaming", telemetry=tel)
    ledger = ValidationLedger(os.path.join(workdir, "ledger.jsonl"),
                              expected_tasks=suite.task_names,
                              telemetry=tel)
    try:
        suite.validate_params(params)           # warm-up (jit compile)
        tel.flush()
        n_warm = len(load_traces([trace]))
        for r in range(1, repeats + 1):
            ledger.record(suite.validate_params(params, step=r))
        tel.flush()
        records = load_traces([trace])
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    steady = records[n_warm:]
    idles = [rec["idle_ratio"] for rec in steady
             if rec["name"] == "staged"]
    return records, idles


def main():
    print("name,engine,subset,passages,total_s,encode_s,mrr")
    by_engine = {}
    for engine in ("streaming", "materialized"):
        rows = by_engine[engine] = run(engine=engine)
        for r in rows:
            print(f"validation_time,{r['engine']},{r['subset']},{r['size']},"
                  f"{r['total_s']:.3f},{r['encode_s']:.3f},{r['mrr']:.4f}")
        full = next(r for r in rows if r["subset"] == "full")
        small = min(rows, key=lambda r: r["size"])
        print(f"validation_time,{engine},speedup_full_vs_smallest,"
              f"{full['total_s']/max(small['total_s'],1e-9):.2f},,,")
        assert small["total_s"] <= full["total_s"], \
            "subset validation must be faster than full-corpus validation"
    # both engines must agree on every subset's metric (same checkpoints;
    # 1e-6: separately-compiled programs may differ by an ulp in scores)
    for rs, rm in zip(by_engine["streaming"], by_engine["materialized"]):
        assert abs(rs["mrr"] - rm["mrr"]) < 1e-6, (rs, rm)

    # per-stage breakdown from the lifecycle tracer + staging idle gate
    records, idles = run_breakdown()
    print("\nper-stage breakdown (traced, incl. warm-up/compile run):")
    print(breakdown_table(records))
    assert idles, "no steady-state staged spans traced"
    mean_idle = sum(idles) / len(idles)
    print(f"validation_time,staging_idle_ratio,{mean_idle:.4f},"
          f"gate<{IDLE_GATE:.3f},,,")
    # PR 2's double-buffering claim, continuously measured: the scan loop
    # must spend <10% of its wall time waiting on host->device staging
    assert mean_idle < IDLE_GATE, \
        f"staging idle-gap {mean_idle:.3f} >= {IDLE_GATE:.3f} in the " \
        "double-buffered config"
    return by_engine["streaming"] + [
        {"subset": "staging_idle", "mean_idle_ratio": mean_idle,
         "gate": IDLE_GATE, "n_runs": len(idles)}]


if __name__ == "__main__":
    main()
