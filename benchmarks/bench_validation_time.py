"""Paper Figure 2 (right): per-checkpoint validation time vs subset depth.

The paper: full corpus ~2 h, top-1000 ~1 h, top-100 ~10 min on MS MARCO.
Here: wall-clock validation time across subset depths on the synthetic
corpus — the shape of the scaling (linear in encoded passages, dominated by
corpus encoding) is the reproduced artifact.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import Timer, toy_spec, train_toy_dr
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.core.samplers import FullCorpus, RunFileTopK
from repro.data import corpus as corpus_lib


def run(corpus_size: int = 4000, n_queries: int = 60,
        depths=(5, 20, 50, 200), seed: int = 0, repeats: int = 3,
        engine: str = "streaming"):
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries)
    baseline = corpus_lib.lexical_baseline_run(ds, k=max(depths))
    spec = toy_spec(ds.vocab)
    params, _ = train_toy_dr(ds, spec, steps=50, seed=seed)
    vcfg = ValidationConfig(metrics=("MRR@10",), k=100, batch_size=128,
                            engine=engine)

    rows = []
    samplers = [("full", FullCorpus())] + \
        [(f"top{d}", RunFileTopK(depth=d)) for d in depths]
    for name, sampler in samplers:
        pipe = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                                  vcfg, sampler=sampler,
                                  baseline_run=baseline)
        pipe.validate_params(params)            # warm-up (jit compile)
        times, encode_times = [], []
        for r in range(repeats):
            res = pipe.validate_params(params, step=r)
            times.append(res.timings["total_s"])
            encode_times.append(res.timings["encode_corpus_s"])
        rows.append({"engine": engine, "subset": name,
                     "size": pipe.subset.size,
                     "total_s": min(times),
                     "encode_s": min(encode_times),
                     "mrr": res.metrics["MRR@10"]})
    return rows


def main():
    print("name,engine,subset,passages,total_s,encode_s,mrr")
    by_engine = {}
    for engine in ("streaming", "materialized"):
        rows = by_engine[engine] = run(engine=engine)
        for r in rows:
            print(f"validation_time,{r['engine']},{r['subset']},{r['size']},"
                  f"{r['total_s']:.3f},{r['encode_s']:.3f},{r['mrr']:.4f}")
        full = next(r for r in rows if r["subset"] == "full")
        small = min(rows, key=lambda r: r["size"])
        print(f"validation_time,{engine},speedup_full_vs_smallest,"
              f"{full['total_s']/max(small['total_s'],1e-9):.2f},,,")
        assert small["total_s"] <= full["total_s"], \
            "subset validation must be faster than full-corpus validation"
    # both engines must agree on every subset's metric (same checkpoints;
    # 1e-6: separately-compiled programs may differ by an ulp in scores)
    for rs, rm in zip(by_engine["streaming"], by_engine["materialized"]):
        assert abs(rs["mrr"] - rm["mrr"]) < 1e-6, (rs, rm)
    return by_engine["streaming"]


if __name__ == "__main__":
    main()
