"""Streaming vs materialized validation engine: the memory/time win —
plus the staging-overlap case (out-of-core mmap TokenStore, double-buffered
vs synchronous host→device staging).

The legacy path materializes the full (N, D) corpus embedding matrix on host
(one ``np.asarray`` per batch) and copies it back to device for retrieval.
The streaming engine fuses encode→top-k per chunk so peak embedding memory is
``O(chunk x D + Q x k)`` regardless of N — corpora larger than host RAM
become validatable.  This bench measures, at EQUAL chunk size (streaming
chunk == legacy encode batch):

  * wall-clock per checkpoint — streaming must be no worse (it skips the
    device→host→device round trip and the (N, D) concat), and
    double-buffered staging must be no worse than synchronous staging
    (the device_put of chunk i+1 overlaps chunk i's fused step);
  * the peak embedding AND host-token footprints *implied by each path's
    data flow* (analytic accounting, not a process measurement — the
    structural guarantees are enforced by the encoder-shape spy and
    prefetch-depth tests in tests/test_engine.py and
    tests/test_engine_staging.py).  With an mmap-backed store the host
    only ever holds the staged batches: O(depth x window x chunk x L);
  * metric parity — every path scores identically.
"""

from __future__ import annotations

import os
import shutil
import tempfile

from benchmarks.common import toy_spec, train_toy_dr
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.data import corpus as corpus_lib

TOK_BYTES = 4 + 1                    # int32 token + 1-byte bool mask per slot


def run(corpus_size: int = 8000, n_queries: int = 60, chunk: int = 256,
        k: int = 100, seed: int = 0, repeats: int = 9):
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries)
    spec = toy_spec(ds.vocab)
    params, _ = train_toy_dr(ds, spec, steps=50, seed=seed)
    mmap_dir = tempfile.mkdtemp(prefix="asyncval_tokens_")
    try:
        return _run_variants(ds, spec, params, mmap_dir, chunk=chunk, k=k,
                             repeats=repeats, corpus_size=corpus_size,
                             n_queries=n_queries)
    finally:
        shutil.rmtree(mmap_dir, ignore_errors=True)


def _run_variants(ds, spec, params, mmap_dir, *, chunk, k, repeats,
                  corpus_size, n_queries):
    # staging-overlap case runs window=1 so both staged variants carry the
    # ISSUE's O(2 x chunk x L) host-token bound (and sync is O(1 x ...))
    variants = {
        "materialized": dict(engine="materialized"),
        "streaming": dict(engine="streaming"),
        "stream_mmap_sync": dict(engine="streaming", staging="sync",
                                 token_backing="mmap", mmap_dir=mmap_dir,
                                 scan_window=1),
        "stream_mmap_dbuf": dict(engine="streaming",
                                 staging="double_buffered",
                                 token_backing="mmap", mmap_dir=mmap_dir,
                                 scan_window=1),
    }
    pipes = {}
    for name, kw in variants.items():
        vcfg = ValidationConfig(metrics=("MRR@10",), k=k, batch_size=chunk,
                                chunk_size=chunk, **kw)
        pipes[name] = ValidationPipeline(spec, ds.corpus, ds.queries,
                                         ds.qrels, vcfg)
        pipes[name].validate_params(params)        # warm-up (jit compile)

    # interleave the engines per repeat so machine-load drift hits both
    # equally; min-of-repeats then compares best-case against best-case.
    times = {e: [] for e in variants}
    results = {}
    for r in range(repeats):
        for name in variants:
            res = pipes[name].validate_params(params, step=r)
            times[name].append(res.timings["total_s"])
            results[name] = res

    n, d, q, L = corpus_size, spec.dim, n_queries, spec.p_max_len
    n_chunks = -(-n // chunk)
    rows = []
    for name in variants:
        # analytic footprints from the data-flow shapes (module docstring)
        peak_emb = (n * d * 4 if name == "materialized"
                    else chunk * d * 4 + q * k * 8)  # f32 emb + (f32,i32) carry
        if name == "materialized" or name == "streaming":
            # host-resident TokenStore (or per-batch pads over the full pass)
            peak_tok = n_chunks * chunk * L * TOK_BYTES
        else:
            depth = 2 if name.endswith("dbuf") else 1
            peak_tok = depth * chunk * L * TOK_BYTES
        rows.append({"engine": name, "total_s": min(times[name]),
                     "peak_emb_bytes": peak_emb,
                     "peak_host_tok_bytes": peak_tok,
                     "mrr": results[name].metrics["MRR@10"]})
    return rows, results


def main():
    rows, results = run()
    print("name,engine,total_s,peak_emb_bytes,peak_host_tok_bytes,mrr")
    for r in rows:
        print(f"streaming_engine,{r['engine']},{r['total_s']:.3f},"
              f"{r['peak_emb_bytes']},{r['peak_host_tok_bytes']},"
              f"{r['mrr']:.4f}")
    by = {r["engine"]: r for r in rows}
    legacy, stream = by["materialized"], by["streaming"]
    ratio = stream["total_s"] / max(legacy["total_s"], 1e-9)
    shrink = legacy["peak_emb_bytes"] / stream["peak_emb_bytes"]
    stage_ratio = (by["stream_mmap_dbuf"]["total_s"]
                   / max(by["stream_mmap_sync"]["total_s"], 1e-9))
    tok_shrink = (stream["peak_host_tok_bytes"]
                  / by["stream_mmap_dbuf"]["peak_host_tok_bytes"])
    print(f"streaming_engine,time_ratio_stream_over_legacy,{ratio:.3f},,,")
    print(f"streaming_engine,peak_memory_shrink_x,{shrink:.1f},,,")
    print(f"streaming_engine,time_ratio_dbuf_over_sync,{stage_ratio:.3f},,,")
    print(f"streaming_engine,host_token_shrink_x,{tok_shrink:.1f},,,")
    # metric parity with a 1e-6 epsilon: the paths are separately compiled
    # XLA programs, so a compiler upgrade may legally shift scores by an ulp
    # and flip a near-tie rank (exact equality lives in tests/test_engine.py
    # and tests/test_engine_staging.py where sides share program structure).
    for name, v in results["streaming"].metrics.items():
        for other in ("materialized", "stream_mmap_sync", "stream_mmap_dbuf"):
            assert abs(v - results[other].metrics[name]) < 1e-6, \
                (name, other, v, results[other].metrics[name])
    assert stream["peak_emb_bytes"] < legacy["peak_emb_bytes"], \
        "streaming peak embedding memory must be below the (N, D) matrix"
    # out-of-core: host tokens bounded by the double buffer, O(2 x chunk x L)
    assert by["stream_mmap_dbuf"]["peak_host_tok_bytes"] \
        < stream["peak_host_tok_bytes"], \
        "mmap + staged tokens must undercut the host-resident TokenStore"
    # wall-clock gates: 1.05 by default; CI runners are noisy shared
    # tenants, so the workflow widens the slack rather than flaking
    # unrelated PRs.
    slack = float(os.environ.get("ASYNCVAL_BENCH_TIME_SLACK", "1.05"))
    assert ratio <= slack, \
        f"streaming wall-time must be no worse than legacy " \
        f"(ratio={ratio:.3f} > slack={slack})"
    assert stage_ratio <= slack, \
        f"double-buffered staging must be no worse than synchronous " \
        f"(ratio={stage_ratio:.3f} > slack={slack})"
    return rows


if __name__ == "__main__":
    main()
