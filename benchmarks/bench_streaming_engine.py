"""Streaming vs materialized validation engine: the memory/time win —
plus the staging-overlap case (out-of-core mmap TokenStore, double-buffered
vs synchronous host→device staging) and the rerank-at-scale case
(query-blocked vs dense materialized candidate gather; sharded vs
single-device streaming rerank).

The legacy path materializes the full (N, D) corpus embedding matrix on host
(one ``np.asarray`` per batch) and copies it back to device for retrieval.
The streaming engine fuses encode→top-k per chunk so peak embedding memory is
``O(chunk x D + Q x k)`` regardless of N — corpora larger than host RAM
become validatable.  This bench measures, at EQUAL chunk size (streaming
chunk == legacy encode batch):

  * wall-clock per checkpoint — streaming must be no worse (it skips the
    device→host→device round trip and the (N, D) concat), and
    double-buffered staging must be no worse than synchronous staging
    (the device_put of chunk i+1 overlaps chunk i's fused step);
  * the peak embedding AND host-token footprints *implied by each path's
    data flow* (analytic accounting, not a process measurement — the
    structural guarantees are enforced by the encoder-shape spy and
    prefetch-depth tests in tests/test_engine.py and
    tests/test_engine_staging.py).  With an mmap-backed store the host
    only ever holds the staged batches: O(depth x window x chunk x L);
  * metric parity — every path scores identically.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

from benchmarks.common import toy_spec, train_toy_dr
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.data import corpus as corpus_lib

TOK_BYTES = 4 + 1                    # int32 token + 1-byte bool mask per slot


def run(corpus_size: int = 8000, n_queries: int = 60, chunk: int = 256,
        k: int = 100, seed: int = 0, repeats: int = 9):
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries)
    spec = toy_spec(ds.vocab)
    params, _ = train_toy_dr(ds, spec, steps=50, seed=seed)
    mmap_dir = tempfile.mkdtemp(prefix="asyncval_tokens_")
    try:
        return _run_variants(ds, spec, params, mmap_dir, chunk=chunk, k=k,
                             repeats=repeats, corpus_size=corpus_size,
                             n_queries=n_queries)
    finally:
        shutil.rmtree(mmap_dir, ignore_errors=True)


def _run_variants(ds, spec, params, mmap_dir, *, chunk, k, repeats,
                  corpus_size, n_queries):
    # staging-overlap case runs window=1 so both staged variants carry the
    # ISSUE's O(2 x chunk x L) host-token bound (and sync is O(1 x ...))
    variants = {
        "materialized": dict(engine="materialized"),
        "streaming": dict(engine="streaming"),
        "stream_mmap_sync": dict(engine="streaming", staging="sync",
                                 token_backing="mmap", mmap_dir=mmap_dir,
                                 scan_window=1),
        "stream_mmap_dbuf": dict(engine="streaming",
                                 staging="double_buffered",
                                 token_backing="mmap", mmap_dir=mmap_dir,
                                 scan_window=1),
    }
    pipes = {}
    for name, kw in variants.items():
        vcfg = ValidationConfig(metrics=("MRR@10",), k=k, batch_size=chunk,
                                chunk_size=chunk, **kw)
        pipes[name] = ValidationPipeline(spec, ds.corpus, ds.queries,
                                         ds.qrels, vcfg)
        pipes[name].validate_params(params)        # warm-up (jit compile)

    # interleave the engines per repeat so machine-load drift hits both
    # equally; min-of-repeats then compares best-case against best-case.
    times = {e: [] for e in variants}
    results = {}
    for r in range(repeats):
        for name in variants:
            res = pipes[name].validate_params(params, step=r)
            times[name].append(res.timings["total_s"])
            results[name] = res

    n, d, q, L = corpus_size, spec.dim, n_queries, spec.p_max_len
    n_chunks = -(-n // chunk)
    rows = []
    for name in variants:
        # analytic footprints from the data-flow shapes (module docstring)
        peak_emb = (n * d * 4 if name == "materialized"
                    else chunk * d * 4 + q * k * 8)  # f32 emb + (f32,i32) carry
        if name == "materialized" or name == "streaming":
            # host-resident TokenStore (or per-batch pads over the full pass)
            peak_tok = n_chunks * chunk * L * TOK_BYTES
        else:
            depth = 2 if name.endswith("dbuf") else 1
            peak_tok = depth * chunk * L * TOK_BYTES
        rows.append({"engine": name, "total_s": min(times[name]),
                     "peak_emb_bytes": peak_emb,
                     "peak_host_tok_bytes": peak_tok,
                     "mrr": results[name].metrics["MRR@10"]})
    return rows, results


def run_rerank(n_queries: int = 2048, cmax: int = 256,
               corpus_size: int = 4096, dim: int = 16, chunk: int = 256,
               mem_shrink: int = 16, seed: int = 0, repeats: int = 5):
    """Rerank at scale: Q=2048 queries x Cmax=256 candidates (the ISSUE's
    acceptance point), four paths over identical integer-valued embeddings
    (exact float32 dot products, so every path must agree bit for bit):

      * ``rerank_dense``   — materialized, one (Q, Cmax, D) gather;
      * ``rerank_blocked`` — materialized, (Q_block, Cmax, D) per gather
        with Q_block = Q/``mem_shrink`` — peak candidate-block memory drops
        ``mem_shrink``-fold while wall time must stay within 10%;
      * ``rerank_stream``  — streaming single-device StreamRerankStage;
      * ``rerank_sharded`` — streaming ShardedStreamRerankStage on a mesh
        over every local device (1 on the CPU CI host; the multi-device
        behaviour is exercised by tests/test_distributed.py).

    Peak candidate-block bytes are analytic (Q_block x Cmax x D x 4), like
    the module's other footprints: the blocked loop provably never holds
    more than one block (the structural guarantee is the loop itself;
    parity across block sizes is enforced by tests/test_rerank_parity.py).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import engine as E
    from repro.core import retrieval as R
    from repro.distributed import compat

    rng = np.random.default_rng(seed)
    vocab = 64
    table = rng.integers(-4, 5, size=(vocab, dim)).astype(np.float32)
    doc_texts = [[int(i % vocab)] for i in range(corpus_size)]
    c = table[[t[0] for t in doc_texts]]
    q = rng.integers(-4, 5, size=(n_queries, dim)).astype(np.float32)
    qids = [f"q{i}" for i in range(n_queries)]
    dids = [f"d{i}" for i in range(corpus_size)]
    # cmax distinct candidates per query, vectorized draw
    picks = rng.permuted(np.tile(np.arange(corpus_size), (n_queries, 1)),
                         axis=1)[:, :cmax]
    per_query = {qid: [f"d{j}" for j in row]
                 for qid, row in zip(qids, picks)}

    q_block = max(1, n_queries // mem_shrink)
    k = 100

    def dense():
        return R.rerank_run(qids, q, dids, c, per_query, k=k,
                            q_block=n_queries)

    def blocked():
        return R.rerank_run(qids, q, dids, c, per_query, k=k,
                            q_block=q_block)

    params = {"table": jnp.asarray(table)}
    q_dev = jnp.asarray(q)

    def enc(params, tokens, mask):
        return jnp.take(params["table"], tokens[:, 0], axis=0)

    store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
    stages = {
        "rerank_stream": E.StreamRerankStage(
            enc, k=k, query_ids=qids, doc_ids=dids, per_query=per_query,
            store=store),
        "rerank_sharded": E.ShardedStreamRerankStage(
            enc, compat.make_mesh((jax.device_count(),), ("data",)), k=k,
            query_ids=qids, doc_ids=dids, per_query=per_query, store=store),
    }

    def stream(stage):
        def go():
            # honor the compacting rerank stage's packed pseudo-chunk store,
            # exactly like StreamingEngine.run
            st = getattr(stage, "store_override", None) or store
            carry = stage.init(q_dev)
            for toks, mask, base, n_valid in st.chunks():
                if not stage.wants_chunk(base // st.chunk):
                    continue
                carry = stage.step(params, q_dev, carry, toks, mask, base,
                                   n_valid)
            jax.block_until_ready(carry)
            return stage.finalize(carry)
        return go

    fns = {"rerank_dense": dense, "rerank_blocked": blocked,
           **{name: stream(stg) for name, stg in stages.items()}}
    outs = {name: fn() for name, fn in fns.items()}      # warm-up + parity
    times = {name: [] for name in fns}
    for _ in range(repeats):
        for name, fn in fns.items():                     # interleaved
            t0 = time.time()
            fn()
            times[name].append(time.time() - t0)

    cand_bytes = {"rerank_dense": n_queries * cmax * dim * 4,
                  "rerank_blocked": q_block * cmax * dim * 4,
                  # streaming never gathers candidate embeddings at all —
                  # its footprint is the (Q, Cmax) f32 score carry
                  "rerank_stream": n_queries * cmax * 4,
                  "rerank_sharded": n_queries * cmax * 4}
    rows = [{"engine": name, "total_s": min(times[name]),
             "peak_cand_bytes": cand_bytes[name]} for name in fns]
    return rows, outs


def run_rerank_sparse(n_queries: int = 256, cands_per_q: int = 4,
                      corpus_size: int = 8192, dim: int = 16,
                      chunk: int = 64, seed: int = 0, repeats: int = 5):
    """Sparse-rerank gather compaction: at very sparse candidate depths
    (here ~4 candidates/query over a 8192-doc corpus, chunk=64) nearly every
    chunk survives chunk-skipping with only a handful of candidate rows in
    it.  The compacting stage packs those rows into dense pseudo-chunks, so
    encoded rows collapse from ``surviving_chunks x chunk`` to roughly the
    unique-candidate count — bit-for-bit identical output (integer-valued
    embeddings, row-independent encoder).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import engine as E

    rng = np.random.default_rng(seed)
    vocab = 64
    table = rng.integers(-4, 5, size=(vocab, dim)).astype(np.float32)
    doc_texts = [[int(i % vocab)] for i in range(corpus_size)]
    q = rng.integers(-4, 5, size=(n_queries, dim)).astype(np.float32)
    qids = [f"q{i}" for i in range(n_queries)]
    dids = [f"d{i}" for i in range(corpus_size)]
    # spread candidates so nearly every chunk holds at least one: the
    # worst case for chunk-skipping, the best case for compaction
    picks = rng.permuted(np.tile(np.arange(corpus_size), (n_queries, 1)),
                         axis=1)[:, :cands_per_q]
    per_query = {qid: [f"d{j}" for j in row]
                 for qid, row in zip(qids, picks)}
    params = {"table": jnp.asarray(table)}
    q_dev = jnp.asarray(q)

    def enc(params, tokens, mask):
        return jnp.take(params["table"], tokens[:, 0], axis=0)

    store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
    kw = dict(k=10, query_ids=qids, doc_ids=dids, per_query=per_query,
              store=store)
    stages = {"rerank_plain": E.StreamRerankStage(enc, compact=False, **kw),
              "rerank_compact": E.StreamRerankStage(enc, compact=True, **kw)}
    assert stages["rerank_compact"].store_override is not None, \
        "sparse candidates must trigger gather compaction"

    def stream(stage):
        def go():
            st = getattr(stage, "store_override", None) or store
            carry = stage.init(q_dev)
            for toks, mask, base, n_valid in st.chunks():
                if not stage.wants_chunk(base // st.chunk):
                    continue
                carry = stage.step(params, q_dev, carry, toks, mask, base,
                                   n_valid)
            jax.block_until_ready(carry)
            return stage.finalize(carry)
        return go

    fns = {name: stream(stg) for name, stg in stages.items()}
    outs = {name: fn() for name, fn in fns.items()}
    times = {name: [] for name in fns}
    for _ in range(repeats):
        for name, fn in fns.items():
            t0 = time.time()
            fn()
            times[name].append(time.time() - t0)

    surviving = sum(stages["rerank_plain"].wants_chunk(ci)
                    for ci in range(store.n_chunks))
    packed = stages["rerank_compact"].store_override.n_chunks
    rows = [{"engine": "rerank_plain", "total_s": min(times["rerank_plain"]),
             "chunks_encoded": surviving},
            {"engine": "rerank_compact",
             "total_s": min(times["rerank_compact"]),
             "chunks_encoded": packed}]
    return rows, outs


def run_precision(corpus_size: int = 4000, n_queries: int = 48,
                  chunk: int = 256, k: int = 100, seed: int = 0,
                  repeats: int = 5):
    """score_dtype sweep through the full streaming validation pipeline:
    wall time, the analytic per-chunk embedding bytes the fused step moves,
    and metric proximity to the f32 run."""
    from repro.core.precision import itemsize

    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries)
    spec = toy_spec(ds.vocab)
    params, _ = train_toy_dr(ds, spec, steps=50, seed=seed)
    rows, results = [], {}
    for dt in ("f32", "bf16", "int8"):
        vcfg = ValidationConfig(metrics=("MRR@10",), k=k, batch_size=chunk,
                                chunk_size=chunk, engine="streaming",
                                score_dtype=dt)
        pipe = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                                  vcfg)
        pipe.validate_params(params)                    # warm-up
        times = [pipe.validate_params(params, step=r).timings["total_s"]
                 for r in range(repeats)]
        results[dt] = pipe.validate_params(params, step=repeats)
        rows.append({"score_dtype": dt, "total_s": min(times),
                     "chunk_emb_bytes": chunk * spec.dim * itemsize(dt),
                     "mrr": results[dt].metrics["MRR@10"]})
    return rows, results


def main():
    rows, results = run()
    print("name,engine,total_s,peak_emb_bytes,peak_host_tok_bytes,mrr")
    for r in rows:
        print(f"streaming_engine,{r['engine']},{r['total_s']:.3f},"
              f"{r['peak_emb_bytes']},{r['peak_host_tok_bytes']},"
              f"{r['mrr']:.4f}")
    by = {r["engine"]: r for r in rows}
    legacy, stream = by["materialized"], by["streaming"]
    ratio = stream["total_s"] / max(legacy["total_s"], 1e-9)
    shrink = legacy["peak_emb_bytes"] / stream["peak_emb_bytes"]
    stage_ratio = (by["stream_mmap_dbuf"]["total_s"]
                   / max(by["stream_mmap_sync"]["total_s"], 1e-9))
    tok_shrink = (stream["peak_host_tok_bytes"]
                  / by["stream_mmap_dbuf"]["peak_host_tok_bytes"])
    print(f"streaming_engine,time_ratio_stream_over_legacy,{ratio:.3f},,,")
    print(f"streaming_engine,peak_memory_shrink_x,{shrink:.1f},,,")
    print(f"streaming_engine,time_ratio_dbuf_over_sync,{stage_ratio:.3f},,,")
    print(f"streaming_engine,host_token_shrink_x,{tok_shrink:.1f},,,")
    # metric parity with a 1e-6 epsilon: the paths are separately compiled
    # XLA programs, so a compiler upgrade may legally shift scores by an ulp
    # and flip a near-tie rank (exact equality lives in tests/test_engine.py
    # and tests/test_engine_staging.py where sides share program structure).
    for name, v in results["streaming"].metrics.items():
        for other in ("materialized", "stream_mmap_sync", "stream_mmap_dbuf"):
            assert abs(v - results[other].metrics[name]) < 1e-6, \
                (name, other, v, results[other].metrics[name])
    assert stream["peak_emb_bytes"] < legacy["peak_emb_bytes"], \
        "streaming peak embedding memory must be below the (N, D) matrix"
    # out-of-core: host tokens bounded by the double buffer, O(2 x chunk x L)
    assert by["stream_mmap_dbuf"]["peak_host_tok_bytes"] \
        < stream["peak_host_tok_bytes"], \
        "mmap + staged tokens must undercut the host-resident TokenStore"
    # wall-clock gates: 1.05 by default; CI runners are noisy shared
    # tenants, so the workflow widens the slack rather than flaking
    # unrelated PRs.
    slack = float(os.environ.get("ASYNCVAL_BENCH_TIME_SLACK", "1.05"))
    assert ratio <= slack, \
        f"streaming wall-time must be no worse than legacy " \
        f"(ratio={ratio:.3f} > slack={slack})"
    assert stage_ratio <= slack, \
        f"double-buffered staging must be no worse than synchronous " \
        f"(ratio={stage_ratio:.3f} > slack={slack})"

    # -- rerank at scale: Q=2048, Cmax=256 ---------------------------------
    rrows, routs = run_rerank()
    print("name,engine,total_s,peak_cand_bytes,,")
    for r in rrows:
        print(f"rerank_scale,{r['engine']},{r['total_s']:.3f},"
              f"{r['peak_cand_bytes']},,")
    rby = {r["engine"]: r for r in rrows}
    mem_ratio = (rby["rerank_dense"]["peak_cand_bytes"]
                 / rby["rerank_blocked"]["peak_cand_bytes"])
    rr_time = (rby["rerank_blocked"]["total_s"]
               / max(rby["rerank_dense"]["total_s"], 1e-9))
    sh_time = (rby["rerank_sharded"]["total_s"]
               / max(rby["rerank_stream"]["total_s"], 1e-9))
    print(f"rerank_scale,cand_block_shrink_x,{mem_ratio:.1f},,,")
    print(f"rerank_scale,time_ratio_blocked_over_dense,{rr_time:.3f},,,")
    print(f"rerank_scale,time_ratio_sharded_over_single,{sh_time:.3f},,,")
    # integer-valued embeddings: every rerank path must agree bit for bit
    # (runs AND scores), not just to a metric epsilon.
    for name, got in routs.items():
        assert got == routs["rerank_dense"], \
            f"rerank path {name} diverged from the dense gather"
    assert mem_ratio >= 8, \
        f"blocked gather must cut peak candidate-block memory >= 8x " \
        f"(got {mem_ratio:.1f}x)"
    # acceptance bar: blocked within 10% of the dense gather's wall time
    # (same CI noise widening as the other wall-clock gates)
    rr_slack = 1.10 * slack / 1.05
    assert rr_time <= rr_slack, \
        f"blocked rerank gather must stay within 10% of dense wall time " \
        f"(ratio={rr_time:.3f} > {rr_slack:.3f})"

    # -- sparse-rerank gather compaction (PR-6) ----------------------------
    srows, souts = run_rerank_sparse()
    print("name,engine,total_s,chunks_encoded,,")
    for r in srows:
        print(f"rerank_sparse,{r['engine']},{r['total_s']:.3f},"
              f"{r['chunks_encoded']},,")
    sby = {r["engine"]: r for r in srows}
    chunk_shrink = (sby["rerank_plain"]["chunks_encoded"]
                    / max(sby["rerank_compact"]["chunks_encoded"], 1))
    print(f"rerank_sparse,chunks_encoded_shrink_x,{chunk_shrink:.1f},,,")
    assert souts["rerank_compact"] == souts["rerank_plain"], \
        "compacted sparse rerank diverged from the plain stream"
    assert chunk_shrink >= 2, \
        f"gather compaction must at least halve encoded chunks at sparse " \
        f"depths (got {chunk_shrink:.1f}x)"

    # -- score_dtype sweep through the streaming pipeline (PR-6) -----------
    prows, presults = run_precision()
    print("name,score_dtype,total_s,chunk_emb_bytes,mrr,")
    for r in prows:
        print(f"stream_precision,{r['score_dtype']},{r['total_s']:.3f},"
              f"{r['chunk_emb_bytes']},{r['mrr']:.4f},")
    pby = {r["score_dtype"]: r for r in prows}
    emb_shrink = (pby["f32"]["chunk_emb_bytes"]
                  / pby["bf16"]["chunk_emb_bytes"])
    print(f"stream_precision,bf16_chunk_emb_shrink_x,{emb_shrink:.1f},,,")
    assert emb_shrink >= 2.0, \
        "bf16 must halve the per-chunk embedding bytes the step moves"
    for dt in ("bf16", "int8"):
        delta = abs(pby[dt]["mrr"] - pby["f32"]["mrr"])
        assert delta <= 0.05, \
            f"{dt} validation must stay near the f32 metric " \
            f"(|delta MRR@10|={delta:.4f})"
    return rows


if __name__ == "__main__":
    main()
