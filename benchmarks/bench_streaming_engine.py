"""Streaming vs materialized validation engine: the memory/time win.

The legacy path materializes the full (N, D) corpus embedding matrix on host
(one ``np.asarray`` per batch) and copies it back to device for retrieval.
The streaming engine fuses encode→top-k per chunk so peak embedding memory is
``O(chunk x D + Q x k)`` regardless of N — corpora larger than host RAM
become validatable.  This bench measures, at EQUAL chunk size (streaming
chunk == legacy encode batch):

  * wall-clock per checkpoint — streaming must be no worse (it skips the
    device→host→device round trip and the (N, D) concat);
  * the peak embedding footprint *implied by each path's data flow*
    (analytic accounting, not a process measurement — the structural
    guarantee that streaming never holds more than one chunk of embeddings
    is enforced by the encoder-shape spy test in tests/test_engine.py);
  * metric parity — both paths score identically.
"""

from __future__ import annotations

import os

from benchmarks.common import toy_spec, train_toy_dr
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.data import corpus as corpus_lib


def run(corpus_size: int = 8000, n_queries: int = 60, chunk: int = 256,
        k: int = 100, seed: int = 0, repeats: int = 9):
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries)
    spec = toy_spec(ds.vocab)
    params, _ = train_toy_dr(ds, spec, steps=50, seed=seed)

    engines = ("materialized", "streaming")
    pipes = {}
    for engine in engines:
        vcfg = ValidationConfig(metrics=("MRR@10",), k=k, batch_size=chunk,
                                chunk_size=chunk, engine=engine)
        pipes[engine] = ValidationPipeline(spec, ds.corpus, ds.queries,
                                           ds.qrels, vcfg)
        pipes[engine].validate_params(params)      # warm-up (jit compile)

    # interleave the engines per repeat so machine-load drift hits both
    # equally; min-of-repeats then compares best-case against best-case.
    times = {e: [] for e in engines}
    results = {}
    for r in range(repeats):
        for engine in engines:
            res = pipes[engine].validate_params(params, step=r)
            times[engine].append(res.timings["total_s"])
            results[engine] = res

    rows = []
    for engine in engines:
        n, d, q = corpus_size, spec.dim, n_queries
        # analytic footprint from the data-flow shapes (see module docstring)
        peak = (n * d * 4 if engine == "materialized"
                else chunk * d * 4 + q * k * 8)    # f32 emb + (f32,i32) carry
        rows.append({"engine": engine, "total_s": min(times[engine]),
                     "peak_emb_bytes": peak,
                     "mrr": results[engine].metrics["MRR@10"]})
    return rows, results


def main():
    rows, results = run()
    print("name,engine,total_s,peak_emb_bytes,mrr")
    for r in rows:
        print(f"streaming_engine,{r['engine']},{r['total_s']:.3f},"
              f"{r['peak_emb_bytes']},{r['mrr']:.4f}")
    legacy = next(r for r in rows if r["engine"] == "materialized")
    stream = next(r for r in rows if r["engine"] == "streaming")
    ratio = stream["total_s"] / max(legacy["total_s"], 1e-9)
    shrink = legacy["peak_emb_bytes"] / stream["peak_emb_bytes"]
    print(f"streaming_engine,time_ratio_stream_over_legacy,{ratio:.3f},,")
    print(f"streaming_engine,peak_memory_shrink_x,{shrink:.1f},,")
    # metric parity with a 1e-6 epsilon: the two paths are separately
    # compiled XLA programs, so a compiler upgrade may legally shift scores
    # by an ulp and flip a near-tie rank (exact equality lives in
    # tests/test_engine.py where both sides share one program structure).
    for name, v in results["streaming"].metrics.items():
        assert abs(v - results["materialized"].metrics[name]) < 1e-6, \
            (name, v, results["materialized"].metrics[name])
    assert stream["peak_emb_bytes"] < legacy["peak_emb_bytes"], \
        "streaming peak embedding memory must be below the (N, D) matrix"
    # wall-clock gate: 1.05 by default; CI runners are noisy shared tenants,
    # so the workflow widens the slack rather than flaking unrelated PRs.
    slack = float(os.environ.get("ASYNCVAL_BENCH_TIME_SLACK", "1.05"))
    assert ratio <= slack, \
        f"streaming wall-time must be no worse than legacy " \
        f"(ratio={ratio:.3f} > slack={slack})"
    return rows


if __name__ == "__main__":
    main()
