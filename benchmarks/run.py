"""Benchmark aggregator: one benchmark per paper figure + the kernel bench.

    PYTHONPATH=src python -m benchmarks.run [--only name]

Prints CSV rows (``name,...``) per benchmark; asserts each benchmark's
paper-claim invariants (see individual modules).  The dry-run/roofline
tables are produced separately by ``repro.launch.dryrun`` (they need the
512-device environment).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = ("async_schedule", "fidelity", "validation_time",
           "streaming_engine", "mips_kernel")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    failures = []
    for name in BENCHES:
        if args.only and args.only not in name:
            continue
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["main"])
        print(f"### bench_{name}")
        t0 = time.time()
        try:
            mod.main()
            print(f"### bench_{name}: OK ({time.time()-t0:.1f}s)\n")
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"### bench_{name}: FAILED\n")
    if failures:
        print("FAILED:", ", ".join(failures))
        return 1
    print("all benchmarks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
