"""Benchmark aggregator: one benchmark per paper figure + the kernel bench.

    PYTHONPATH=src python -m benchmarks.run [--only name] [--json out.json]

Prints CSV rows (``name,...``) per benchmark; asserts each benchmark's
paper-claim invariants (see individual modules).  Each benchmark's
``main()`` return value (rows of dicts, or None) is collected into a
machine-readable JSON report — ``BENCH_10.json`` next to this file by
default — whose headline is the checkpoint-to-verdict p50/p99 from
``bench_async_schedule``'s telemetry (watcher and lazy hand-off routes),
so the staleness trajectory is tracked across PRs.  The dry-run/roofline tables are produced separately
by ``repro.launch.dryrun`` (they need the 512-device environment).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

BENCHES = ("async_schedule", "fidelity", "validation_time",
           "streaming_engine", "mips_kernel")

DEFAULT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_10.json")


def _headline(results):
    """Pull the cross-PR tracked numbers out of the per-bench rows."""
    head = {}
    for row in results.get("async_schedule") or []:
        if not isinstance(row, dict) or "ckpt_to_verdict_p50_s" not in row:
            continue
        if row.get("mode") == "async":
            head["ckpt_to_verdict_p50_s"] = row["ckpt_to_verdict_p50_s"]
            head["ckpt_to_verdict_p99_s"] = row["ckpt_to_verdict_p99_s"]
        elif row.get("mode") == "handoff":
            # the lazy hand-off (PR 10) staleness number: commit-to-verdict
            # with the snapshot route on — tracked next to the watcher path
            head["handoff_ckpt_to_verdict_p50_s"] = \
                row["ckpt_to_verdict_p50_s"]
    return head


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default=DEFAULT_JSON,
                    help="machine-readable report path ('' disables)")
    args = ap.parse_args()

    failures = []
    results = {}
    for name in BENCHES:
        if args.only and args.only not in name:
            continue
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["main"])
        print(f"### bench_{name}")
        t0 = time.time()
        try:
            results[name] = mod.main()
            print(f"### bench_{name}: OK ({time.time()-t0:.1f}s)\n")
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"### bench_{name}: FAILED\n")
    if args.json:
        report = {"benches": results, "failed": failures,
                  **_headline(results)}
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True, default=str)
            f.write("\n")
        print(f"report: {args.json}")
    if failures:
        print("FAILED:", ", ".join(failures))
        return 1
    print("all benchmarks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
