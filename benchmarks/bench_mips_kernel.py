"""MIPS retrieval micro-benchmark (ours — the paper's retrieval hot path).

Measures the XLA blocked top-k scan (the CPU-runnable twin of the Pallas
``topk_mips`` kernel) across corpus sizes and block sizes, and reports the
kernel's arithmetic-intensity roofline position: Q x N x D MACs over
(Q + N) x D reads — for small Q the scan is HBM-bandwidth-bound by design,
which is why the kernel keeps the running top-k in VMEM rather than
round-tripping candidates.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import itemsize
from repro.core.retrieval import topk_exact


def _bench(fn, *args, repeats=5, **kw):
    fn(*args, **kw)[0].block_until_ready()            # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)[0].block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run(Q: int = 64, D: int = 128, k: int = 100,
        corpus_sizes=(10_000, 50_000, 200_000), blocks=(1024, 4096, 16384),
        seed: int = 0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(Q, D)), jnp.float32)
    rows = []
    for N in corpus_sizes:
        c = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
        for block in blocks:
            dt = _bench(topk_exact, q, c, k=k, block=block)
            flops = 2.0 * Q * N * D
            bytes_rd = 4.0 * (Q + N) * D
            rows.append({
                "N": N, "block": block, "ms": dt * 1e3,
                "gflops_s": flops / dt / 1e9,
                "gbytes_s": bytes_rd / dt / 1e9,
                "arith_intensity": flops / bytes_rd,
            })
    return rows


def run_precision(Q: int = 64, D: int = 128, k: int = 100,
                  N: int = 50_000, block: int = 4096, seed: int = 0):
    """Precision sweep at the default bench point: wall time, throughput,
    and the analytic corpus-embedding footprint per ``score_dtype``.

    The byte figure is analytic (N x D x itemsize) — it is what the kernel
    streams from HBM per scan on an accelerator, and it is exact regardless
    of CPU-CI wall-clock noise; the PR-6 acceptance gate (bf16 at >= 1.5x
    throughput OR >= 2x embedding-byte shrink vs f32) therefore always has
    the deterministic arm available.
    """
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(Q, D)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    rows = []
    for dt in ("f32", "bf16", "int8"):
        best = _bench(topk_exact, q, c, k=k, block=block, score_dtype=dt)
        flops = 2.0 * Q * N * D
        emb_bytes = N * D * itemsize(dt)
        rows.append({
            "score_dtype": dt, "N": N, "block": block, "ms": best * 1e3,
            "gflops_s": flops / best / 1e9, "emb_bytes": emb_bytes,
        })
    return rows


def main():
    rows = run()
    print("name,N,block,ms,gflops_s,gbytes_s,arith_intensity")
    for r in rows:
        print(f"mips_kernel,{r['N']},{r['block']},{r['ms']:.2f},"
              f"{r['gflops_s']:.2f},{r['gbytes_s']:.2f},"
              f"{r['arith_intensity']:.1f}")

    # -- precision sweep (PR-6): score_dtype axis at the default point -----
    prows = run_precision()
    print("name,score_dtype,N,block,ms,gflops_s,emb_bytes")
    for r in prows:
        print(f"mips_precision,{r['score_dtype']},{r['N']},{r['block']},"
              f"{r['ms']:.2f},{r['gflops_s']:.2f},{r['emb_bytes']}")
    by = {r["score_dtype"]: r for r in prows}
    speedup = by["f32"]["ms"] / max(by["bf16"]["ms"], 1e-9)
    shrink = by["f32"]["emb_bytes"] / by["bf16"]["emb_bytes"]
    print(f"mips_precision,bf16_throughput_x,{speedup:.2f},,,,")
    print(f"mips_precision,bf16_emb_byte_shrink_x,{shrink:.1f},,,,")
    assert speedup >= 1.5 or shrink >= 2.0, \
        f"bf16 must win >= 1.5x throughput or >= 2x embedding bytes vs " \
        f"f32 (got {speedup:.2f}x / {shrink:.1f}x)"
    return rows


if __name__ == "__main__":
    main()
