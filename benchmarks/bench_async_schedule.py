"""Paper Figure 1: total train+validate wall time, standard vs Asyncval.

Trains the toy DR producing n checkpoints; validates each with the real
ValidationPipeline either inline (Fig. 1a) or on the async validator thread
(Fig. 1b).  Verifies the pipelining law:

    sync_total  ~= sum(train_i) + sum(val_i)
    async_total ~= sum(train_i) + val_last        (val gap < train gap)
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import jax

from benchmarks.common import Timer, contrastive_step, toy_spec, train_toy_dr
from repro.ckpt import checkpoint as ckpt
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.core.samplers import RunFileTopK
from repro.core.validator import AsyncValidator
from repro.data import corpus as corpus_lib


def run(n_ckpts: int = 4, steps_per_ckpt: int = 40, corpus_size: int = 1500,
        n_queries: int = 60, depth: int = 40, seed: int = 0):
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries)
    baseline = corpus_lib.lexical_baseline_run(ds, k=depth)
    spec = toy_spec(ds.vocab)
    vcfg = ValidationConfig(metrics=("MRR@10",), k=100, batch_size=128)
    rows = []

    for mode in ("sync", "async"):
        workdir = tempfile.mkdtemp(prefix=f"asyncval_{mode}_")
        ckdir = os.path.join(workdir, "ckpts")
        pipe = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                                  vcfg, sampler=RunFileTopK(depth=depth),
                                  baseline_run=baseline)
        validator = AsyncValidator(ckdir, pipe, poll_interval_s=0.02)
        t_train, t_val = [], []

        with Timer() as total:
            if mode == "async":
                validator.start()
            params = spec.init(jax.random.PRNGKey(seed))
            import numpy as np
            import jax.numpy as jnp
            step_fn = contrastive_step(spec)
            rng = np.random.default_rng(seed)
            qids = sorted(ds.qrels)
            step = 0
            for c in range(1, n_ckpts + 1):
                with Timer() as tt:
                    for _ in range(steps_per_ckpt):
                        step += 1
                        pick = rng.choice(len(qids), size=32)
                        q_tok = [ds.queries[qids[j]] for j in pick]
                        p_tok = [ds.corpus[next(iter(ds.qrels[qids[j]]))]
                                 for j in pick]
                        qt, qm = corpus_lib.pad_batch(q_tok, spec.q_max_len)
                        pt, pm = corpus_lib.pad_batch(p_tok, spec.p_max_len)
                        params, _ = step_fn(
                            params, {"q_tokens": jnp.asarray(qt),
                                     "q_mask": jnp.asarray(qm),
                                     "p_tokens": jnp.asarray(pt),
                                     "p_mask": jnp.asarray(pm)})
                    ckpt.save(ckdir, step, {"params": params})
                t_train.append(tt.seconds)
                if mode == "sync":
                    with Timer() as tv:
                        validator.validate_pending()
                    t_val.append(tv.seconds)
            if mode == "async":
                validator.stop(drain=True)
        shutil.rmtree(workdir, ignore_errors=True)

        val_total = sum(r.timings["total_s"] for r in validator.results)
        rows.append({
            "mode": mode, "total_s": total.seconds,
            "train_s": sum(t_train), "validate_s": val_total,
            "n_validated": len(validator.results),
            "mrr_last": validator.results[-1].metrics["MRR@10"]
            if validator.results else float("nan"),
        })
    return rows


def main():
    rows = run()
    sync = next(r for r in rows if r["mode"] == "sync")
    asyn = next(r for r in rows if r["mode"] == "async")
    speedup = sync["total_s"] / asyn["total_s"]
    print("name,mode,total_s,train_s,validate_s,n_validated,mrr_last")
    for r in rows:
        print(f"async_schedule,{r['mode']},{r['total_s']:.2f},"
              f"{r['train_s']:.2f},{r['validate_s']:.2f},"
              f"{r['n_validated']},{r['mrr_last']:.4f}")
    print(f"async_schedule,speedup,{speedup:.3f},,,,")
    # pipelining law (paper Fig. 1): async ~ train + last validation
    assert asyn["total_s"] < sync["total_s"], "async must beat sync"
    return rows


if __name__ == "__main__":
    main()
