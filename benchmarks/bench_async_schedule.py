"""Paper Figure 1: total train+validate wall time, standard vs Asyncval.

Trains the toy DR producing n checkpoints; validates each with the real
ValidationPipeline either inline (Fig. 1a) or on the async validator thread
(Fig. 1b).  Verifies the pipelining law:

    sync_total  ~= sum(train_i) + sum(val_i)
    async_total ~= sum(train_i) + val_last        (val gap < train gap)
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import jax

from benchmarks.common import Timer, contrastive_step, toy_spec, train_toy_dr
from repro.ckpt import checkpoint as ckpt
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.core.samplers import RunFileTopK
from repro.core.validator import CKPT_TO_VERDICT_METRIC, AsyncValidator
from repro.data import corpus as corpus_lib
from repro.obs import Telemetry


def run(n_ckpts: int = 4, steps_per_ckpt: int = 40, corpus_size: int = 1500,
        n_queries: int = 60, depth: int = 40, seed: int = 0):
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries)
    baseline = corpus_lib.lexical_baseline_run(ds, k=depth)
    spec = toy_spec(ds.vocab)
    vcfg = ValidationConfig(metrics=("MRR@10",), k=100, batch_size=128)
    rows = []

    for mode in ("sync", "async"):
        workdir = tempfile.mkdtemp(prefix=f"asyncval_{mode}_")
        ckdir = os.path.join(workdir, "ckpts")
        pipe = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                                  vcfg, sampler=RunFileTopK(depth=depth),
                                  baseline_run=baseline)
        # metrics-only telemetry (no trace file): measures the paper's
        # staleness number — checkpoint commit to verdict — for the async
        # row, so BENCH_9.json tracks it across PRs
        tel = Telemetry(None) if mode == "async" else None
        validator = AsyncValidator(ckdir, pipe, poll_interval_s=0.02,
                                   telemetry=tel)
        t_train, t_val = [], []

        with Timer() as total:
            if mode == "async":
                validator.start()
            params = spec.init(jax.random.PRNGKey(seed))
            import numpy as np
            import jax.numpy as jnp
            step_fn = contrastive_step(spec)
            rng = np.random.default_rng(seed)
            qids = sorted(ds.qrels)
            step = 0
            for c in range(1, n_ckpts + 1):
                with Timer() as tt:
                    for _ in range(steps_per_ckpt):
                        step += 1
                        pick = rng.choice(len(qids), size=32)
                        q_tok = [ds.queries[qids[j]] for j in pick]
                        p_tok = [ds.corpus[next(iter(ds.qrels[qids[j]]))]
                                 for j in pick]
                        qt, qm = corpus_lib.pad_batch(q_tok, spec.q_max_len)
                        pt, pm = corpus_lib.pad_batch(p_tok, spec.p_max_len)
                        params, _ = step_fn(
                            params, {"q_tokens": jnp.asarray(qt),
                                     "q_mask": jnp.asarray(qm),
                                     "p_tokens": jnp.asarray(pt),
                                     "p_mask": jnp.asarray(pm)})
                    ckpt.save(ckdir, step, {"params": params})
                t_train.append(tt.seconds)
                if mode == "sync":
                    with Timer() as tv:
                        validator.validate_pending()
                    t_val.append(tv.seconds)
            if mode == "async":
                validator.stop(drain=True)
        shutil.rmtree(workdir, ignore_errors=True)

        val_total = sum(r.timings["total_s"] for r in validator.results)
        row = {
            "mode": mode, "total_s": total.seconds,
            "train_s": sum(t_train), "validate_s": val_total,
            "n_validated": len(validator.results),
            "mrr_last": validator.results[-1].metrics["MRR@10"]
            if validator.results else float("nan"),
        }
        if tel is not None:
            hist = tel.metrics.get(CKPT_TO_VERDICT_METRIC)
            if hist is not None and hist.count:
                row["ckpt_to_verdict_p50_s"] = hist.percentile(50)
                row["ckpt_to_verdict_p99_s"] = hist.percentile(99)
        rows.append(row)
    return rows


def run_fleet(n_steps: int = 2, unit_s: float = 0.3):
    """Fleet scaling law: N workers claiming (step, task) units from one
    ledger work queue drain a multi-task backlog ~N times faster than a
    single worker — the wall time is the longest per-worker chain, not the
    sum of units.  Gated at <= 0.6x single-worker time for 2 workers."""
    import threading

    import jax.numpy as jnp

    from repro.core.suite import ValidationResult
    from repro.core.validator import ValidationLedger, ValidatorWorker
    from repro.core.workqueue import WorkQueue, WorkUnit, replay

    tasks = ("dev", "heldout", "smoke")

    class SleepyPipeline:
        """Each unit costs ``unit_s`` of pure engine time (sleep)."""
        task_names = tasks

        def run_unit(self, params, unit, engine=None, write_runs=None):
            time.sleep(unit_s)
            return ValidationResult(
                step=unit.step, metrics={"MRR@10": 0.5},
                timings={"total_s": unit_s}, subset_size=1,
                engine="sleepy", task=unit.task)

    def drain(worker):
        while True:
            if worker.run_once():
                continue
            state = worker.queue.refresh()
            if not state.claimable(worker.queue.capabilities) \
                    and not state.blocked():
                return
            time.sleep(0.005)

    rows = []
    for n_workers in (1, 2):
        workdir = tempfile.mkdtemp(prefix=f"asyncval_fleet{n_workers}_")
        ckdir = os.path.join(workdir, "ckpts")
        ledger = os.path.join(workdir, "ledger.jsonl")
        pipe = SleepyPipeline()
        units = []
        for step in range(1, n_steps + 1):
            ckpt.save(ckdir, step, {"params": {"x": jnp.zeros(1)}})
            units += [WorkUnit.make(step, t) for t in tasks]
        workers = []
        for i in range(n_workers):
            queue = WorkQueue(ledger, f"w{i}", lease_ttl=64)
            workers.append(ValidatorWorker(
                ckdir, pipe,
                ledger=ValidationLedger(ledger, expected_tasks=tasks),
                queue=queue, worker_id=f"w{i}",
                params_extractor=lambda s: s["params"]))
        workers[0].queue.publish(units)
        with Timer() as total:
            threads = [threading.Thread(target=drain, args=(w,))
                       for w in workers]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        state = replay(ledger, lease_ttl=64)
        assert len(state.completed_units()) == len(units), \
            f"fleet left units behind: {state.completed_units()}"
        rows.append({"mode": f"fleet{n_workers}", "total_s": total.seconds,
                     "n_units": len(units), "n_workers": n_workers})
        shutil.rmtree(workdir, ignore_errors=True)
    return rows


def run_handoff(n_ckpts: int = 6, param_mb: float = 4.0,
                val_s: float = 0.01, poll_s: float = 0.05):
    """Lazy snapshot hand-off law (PR 10): publishing the host-resident
    params the moment they land cuts checkpoint-to-verdict latency to the
    validation cost alone — the watcher path pays the durable two-phase
    commit PLUS up to a poll interval before scoring even starts.  Gated at
    p50(handoff) <= 0.5x p50(watcher)."""
    import numpy as np

    from repro.core.suite import ValidationResult
    from repro.handoff import ParamSnapshot, SnapshotChannel

    class SleepyPipeline:
        """Scoring costs a fixed ``val_s`` — identical on both routes, so
        the measured gap is pure hand-off latency."""
        task_names = ("default",)

        def validate_params(self, params, step=0, engine=None):
            time.sleep(val_s)
            return ValidationResult(
                step=step, metrics={"MRR@10": 0.5},
                timings={"total_s": val_s}, subset_size=1,
                engine="sleepy")

    # a realistically sized state tree: the durable save fsyncs it, the
    # snapshot route hands the same host bytes over for free
    leaf = np.arange(int(param_mb * 1e6 / 4), dtype=np.float32)
    rows = []
    for mode in ("watcher", "handoff"):
        workdir = tempfile.mkdtemp(prefix=f"asyncval_handoff_{mode}_")
        ckdir = os.path.join(workdir, "ckpts")
        tel = Telemetry(None)
        channel = SnapshotChannel(capacity=n_ckpts + 1, telemetry=tel) \
            if mode == "handoff" else None
        validator = AsyncValidator(ckdir, SleepyPipeline(),
                                   poll_interval_s=poll_s, telemetry=tel,
                                   snapshots=channel)
        validator.start()
        try:
            for step in range(1, n_ckpts + 1):
                state = {"params": {"w": leaf + step}}
                tel.mark("produced", step)   # the trainer's hand-off edge
                if channel is not None:
                    # host copy published first; the durable save races
                    # behind it exactly as the trainer's async-saver hooks
                    # sequence it (publish -> save -> mark_durable)
                    channel.publish(ParamSnapshot.from_tree(step, state))
                    ckpt.save(ckdir, step, state)
                    channel.mark_durable(step)
                else:
                    ckpt.save(ckdir, step, state)
                deadline = time.monotonic() + 30.0
                while step not in validator.ledger:
                    if time.monotonic() > deadline:
                        raise RuntimeError(
                            f"{mode}: no verdict for step {step}")
                    time.sleep(0.002)
        finally:
            validator.stop(drain=True)
        hist = tel.metrics.get(CKPT_TO_VERDICT_METRIC)
        rows.append({"mode": mode,
                     "n_validated": len(validator.results),
                     "ckpt_to_verdict_p50_s": hist.percentile(50),
                     "ckpt_to_verdict_p99_s": hist.percentile(99)})
        shutil.rmtree(workdir, ignore_errors=True)
    return rows


def main():
    rows = run()
    sync = next(r for r in rows if r["mode"] == "sync")
    asyn = next(r for r in rows if r["mode"] == "async")
    speedup = sync["total_s"] / asyn["total_s"]
    print("name,mode,total_s,train_s,validate_s,n_validated,mrr_last")
    for r in rows:
        print(f"async_schedule,{r['mode']},{r['total_s']:.2f},"
              f"{r['train_s']:.2f},{r['validate_s']:.2f},"
              f"{r['n_validated']},{r['mrr_last']:.4f}")
    print(f"async_schedule,speedup,{speedup:.3f},,,,")
    if "ckpt_to_verdict_p50_s" in asyn:
        print(f"async_schedule,ckpt_to_verdict,"
              f"{asyn['ckpt_to_verdict_p50_s']:.3f},"
              f"{asyn['ckpt_to_verdict_p99_s']:.3f},,,")
    # pipelining law (paper Fig. 1): async ~ train + last validation
    assert asyn["total_s"] < sync["total_s"], "async must beat sync"

    fleet = run_fleet()
    solo = next(r for r in fleet if r["n_workers"] == 1)
    duo = next(r for r in fleet if r["n_workers"] == 2)
    ratio = duo["total_s"] / solo["total_s"]
    for r in fleet:
        print(f"async_schedule,{r['mode']},{r['total_s']:.2f},,,"
              f"{r['n_units']},")
    print(f"async_schedule,fleet_ratio,{ratio:.3f},,,,")
    # fleet scaling law: 2 workers split a 6-unit multi-task backlog into
    # ~3-unit chains — well under 0.6x the single-worker wall time even
    # with claim/heartbeat ledger overhead
    assert ratio <= 0.6, \
        f"2-worker fleet must drain in <= 0.6x solo time, got {ratio:.3f}"

    hand = run_handoff()
    watcher = next(r for r in hand if r["mode"] == "watcher")
    handoff = next(r for r in hand if r["mode"] == "handoff")
    for r in hand:
        print(f"async_schedule,{r['mode']},"
              f"{r['ckpt_to_verdict_p50_s']:.4f},"
              f"{r['ckpt_to_verdict_p99_s']:.4f},,"
              f"{r['n_validated']},")
    hratio = handoff["ckpt_to_verdict_p50_s"] \
        / watcher["ckpt_to_verdict_p50_s"]
    print(f"async_schedule,handoff_ratio,{hratio:.3f},,,,")
    # lazy hand-off law (PR 10): snapshot-route verdicts land in at most
    # half the watcher-route checkpoint-to-verdict time — the durable
    # commit and poll-interval wait are off the critical path
    slack = float(os.environ.get("ASYNCVAL_BENCH_TIME_SLACK", "1.0"))
    assert hratio <= 0.5 * slack, \
        f"handoff p50 must be <= 0.5x watcher p50 (x{slack} slack), " \
        f"got {hratio:.3f}"
    return rows + fleet + hand


if __name__ == "__main__":
    main()
