"""Empirical probe: XLA ``cost_analysis()`` counts a scan body ONCE.

This is the measurement behind the dry-run's scan-correction methodology
(EXPERIMENTS.md §Dry-run note 1): a scanned L-layer MLP reports 1-layer
FLOPs regardless of L; fully unrolled it reports L x 1-layer.

    PYTHONPATH=src python -m benchmarks.probe_scan_cost
"""

import os

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp


def model(x, w, L, unroll=1):
    def body(h, wi):
        return jnp.tanh(h @ wi), None
    h, _ = jax.lax.scan(body, x, w, unroll=unroll)
    return h.sum()


def main():
    D = 256
    x = jax.ShapeDtypeStruct((32, D), jnp.float32)
    per_layer = 2 * 32 * D * D
    print(f"analytic per-layer flops: {per_layer:.3e}")
    rows = []
    for L in (2, 4, 8):
        w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        c = jax.jit(model, static_argnums=(2,)).lower(x, w, L).compile()
        f = c.cost_analysis().get("flops", -1.0)
        rows.append(("scan", L, f))
        print(f"scan     L={L}  flops={f:.3e}  (ratio to 1 layer: "
              f"{f/per_layer:.2f})")
    for L in (2, 4):
        w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        c = jax.jit(model, static_argnums=(2, 3)).lower(x, w, L, L).compile()
        f = c.cost_analysis().get("flops", -1.0)
        rows.append(("unrolled", L, f))
        print(f"unrolled L={L}  flops={f:.3e}  (ratio to 1 layer: "
              f"{f/per_layer:.2f})")
    scan_flops = [f for kind, L, f in rows if kind == "scan"]
    assert max(scan_flops) / min(scan_flops) < 1.01, \
        "scan flops should be L-independent (counted once)"
    unr = {L: f for kind, L, f in rows if kind == "unrolled"}
    assert 1.9 < unr[4] / unr[2] < 2.1, "unrolled flops scale with L"
    print("confirmed: scan bodies counted once; unrolled counted x trip")
    return rows


if __name__ == "__main__":
    main()
