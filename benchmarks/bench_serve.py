"""Serving-tier SLO bench: tail latency and swap blackout under load.

Threaded clients hammer the QueryService while the promoter hot-swaps
through a sequence of checkpoints.  The zero-downtime claim becomes two
gates: (1) NO query is dropped, rejected, or mis-attributed across >= 3
promotions — every response names exactly one promoted checkpoint and
the admission controller never sheds load; (2) p99 latency stays under a
toy-corpus bound (widened by ``ASYNCVAL_BENCH_TIME_SLACK``) — a swap
that blocked the request path would spike the tail far past it.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time

from benchmarks.common import Timer, toy_spec, train_toy_dr
from repro.ckpt import checkpoint as ckpt
from repro.data import corpus as corpus_lib
from repro.serve import (AdmissionController, IndexBuilder, Promoter,
                         QueryService, ServeConfig, replay_swaps)

# generous toy-corpus tail bound: a 600-passage index answers in a few
# ms; a swap that held the request path for one index build would push
# the tail past this by an order of magnitude
P99_BOUND_S = 2.0


def run(n_passages: int = 600, n_queries: int = 24, n_clients: int = 4,
        n_promotions: int = 3, settle_s: float = 0.25, seed: int = 0):
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=n_passages, n_queries=n_queries)
    spec = toy_spec(ds.vocab)
    _, snaps = train_toy_dr(ds, spec, steps=20 * n_promotions,
                            snapshot_every=20)
    workdir = tempfile.mkdtemp(prefix="asyncval_serve_")
    try:
        ckdir = os.path.join(workdir, "ckpts")
        for step, params in snaps:
            ckpt.save(ckdir, step, {"params": params})
        steps = [s for s, _ in snaps]

        builder = IndexBuilder(spec, ds.corpus,
                               ServeConfig(k=10, batch_size=64))
        admission = AdmissionController(max_pending=256)
        service = QueryService(spec, k=10, max_batch=8, flush_ms=2.0,
                               admission=admission)
        target = {"step": steps[0]}
        promoter = Promoter(builder, service, ckdir,
                            target_fn=lambda: target["step"],
                            log=os.path.join(workdir, "serve.jsonl"))
        assert promoter.poll_once(), "initial promotion must succeed"
        service.start()

        stop = threading.Event()
        responses, errors = [], []

        def client(i):
            qids = list(ds.queries)
            j = 0
            while not stop.is_set():
                qid = qids[(i + j) % len(qids)]
                j += 1
                try:
                    responses.append(
                        service.submit(qid, ds.queries[qid], timeout=30))
                except BaseException as e:   # any drop IS a blackout
                    errors.append((qid, repr(e)))
                    return

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_clients)]
        with Timer() as total:
            for t in threads:
                t.start()
            for want in steps[1:]:           # promote under sustained load
                time.sleep(settle_s)
                target["step"] = want
                while not promoter.poll_once():
                    time.sleep(0.01)
            time.sleep(settle_s)
            stop.set()
            for t in threads:
                t.join()
        service.stop()

        lat = sorted(r.latency_s for r in responses)
        p50 = lat[len(lat) // 2]
        p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))]
        swaps = replay_swaps(os.path.join(workdir, "serve.jsonl"))
        return {
            "n_responses": len(responses), "n_errors": len(errors),
            "errors": errors[:3], "rejected": admission.rejected,
            "p50_ms": p50 * 1e3, "p99_ms": p99 * 1e3,
            "n_swaps": len(swaps),
            "swap_steps": [s["step"] for s in swaps],
            "served_steps": sorted({r.step for r in responses}),
            "promoter_failures": len(promoter.failures),
            "total_s": total.seconds,
        }
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


def main():
    slack = float(os.environ.get("ASYNCVAL_BENCH_TIME_SLACK", "1.05"))
    r = run()
    print("name,n_responses,n_swaps,p50_ms,p99_ms,rejected,errors,total_s")
    print(f"serve,{r['n_responses']},{r['n_swaps']},{r['p50_ms']:.2f},"
          f"{r['p99_ms']:.2f},{r['rejected']},{r['n_errors']},"
          f"{r['total_s']:.2f}")

    # gate 1 — zero-downtime across >= 3 promotions: nothing dropped,
    # nothing shed, nothing failed, and every response attributes exactly
    # one promoted checkpoint
    assert r["n_swaps"] >= 3, f"expected >=3 promotions, got {r['n_swaps']}"
    assert r["promoter_failures"] == 0
    assert r["n_errors"] == 0, f"dropped queries: {r['errors']}"
    assert r["rejected"] == 0, f"admission shed {r['rejected']} requests"
    assert r["n_responses"] > 0
    assert set(r["served_steps"]) <= set(r["swap_steps"]), \
        (f"responses attributed non-promoted steps: "
         f"{set(r['served_steps']) - set(r['swap_steps'])}")

    # gate 2 — swap blackout: the tail must not see an index build
    bound = P99_BOUND_S * slack
    assert r["p99_ms"] / 1e3 <= bound, \
        f"p99 {r['p99_ms']:.1f}ms exceeds blackout bound {bound * 1e3:.0f}ms"
    return r


if __name__ == "__main__":
    main()
