"""Shared benchmark substrate: a fast trainable toy DR + dataset builders.

The paper's experiments need checkpoints of increasing quality.  The toy
encoder (bag-of-embeddings, 503x32 table) trains to high MRR on the
synthetic topic dataset in seconds on CPU, so every benchmark reproduces a
full checkpoint sequence rather than mocking one.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import corpus as corpus_lib
from repro.models.biencoder import EncoderSpec

DIM = 32


def toy_encode(params, tokens, mask):
    emb = jnp.take(params["table"], tokens, axis=0)
    m = mask.astype(emb.dtype)[..., None]
    v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
    return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def toy_spec(vocab: int, q_max_len=10, p_max_len=26) -> EncoderSpec:
    return EncoderSpec(
        name="toy-dr", dim=DIM, encode_query=toy_encode,
        encode_passage=toy_encode,
        init=lambda rng: {"table": 0.1 * jax.random.normal(rng, (vocab, DIM))},
        q_max_len=q_max_len, p_max_len=p_max_len)


def contrastive_step(spec: EncoderSpec, lr: float = 0.5):
    def loss(params, batch):
        q = spec.encode_query(params, batch["q_tokens"], batch["q_mask"])
        p = spec.encode_passage(params, batch["p_tokens"], batch["p_mask"])
        scores = (q @ p.T) * 10.0
        labels = jnp.arange(q.shape[0])
        lse = jax.nn.logsumexp(scores, axis=-1)
        pos = jnp.take_along_axis(scores, labels[:, None], axis=1)[:, 0]
        return jnp.mean(lse - pos)

    @jax.jit
    def step(params, batch):
        l, g = jax.value_and_grad(loss)(params, batch)
        return jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g), l

    return step


def train_toy_dr(ds, spec: EncoderSpec, *, steps: int, batch: int = 32,
                 seed: int = 0, snapshot_every: int = 0, lr: float = 0.5):
    """Train the toy DR with in-batch negatives; returns (params, snapshots)
    where snapshots is [(step, params), ...] including step 0."""
    step_fn = contrastive_step(spec, lr=lr)
    params = spec.init(jax.random.PRNGKey(seed))
    qids = sorted(ds.qrels)
    snapshots = [(0, params)]
    rng = np.random.default_rng(seed)
    for i in range(1, steps + 1):
        pick = rng.choice(len(qids), size=batch)
        q_tok = [ds.queries[qids[j]] for j in pick]
        p_tok = [ds.corpus[next(iter(ds.qrels[qids[j]]))] for j in pick]
        qt, qm = corpus_lib.pad_batch(q_tok, spec.q_max_len)
        pt, pm = corpus_lib.pad_batch(p_tok, spec.p_max_len)
        params, _ = step_fn(params, {"q_tokens": jnp.asarray(qt),
                                     "q_mask": jnp.asarray(qm),
                                     "p_tokens": jnp.asarray(pt),
                                     "p_mask": jnp.asarray(pm)})
        if snapshot_every and i % snapshot_every == 0:
            snapshots.append((i, params))
    return params, snapshots


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
