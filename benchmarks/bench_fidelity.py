"""Paper Figure 2 (left): validation fidelity of corpus-subset sampling.

Reproduces the paper's three claims on the synthetic dataset:
  1. subset MRR trends track the full-corpus trend across checkpoints
     (high rank correlation);
  2. subsets OVERESTIMATE absolute MRR;
  3. subsets induced by a STRONGER baseline track the full curve closer
     than weak-baseline subsets (TCT-ColBERTv2 vs BM25 in the paper; here
     an oracle+noise run vs the lexical run).
"""

from __future__ import annotations

import jax

from benchmarks.common import toy_spec, train_toy_dr
from repro.core.fidelity import fidelity_report
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.core.samplers import FullCorpus, RunFileTopK
from repro.data import corpus as corpus_lib


def run(corpus_size: int = 3000, n_queries: int = 80, n_ckpts: int = 8,
        steps_per_ckpt: int = 10, depths=(10, 100), seed: int = 0):
    # harder task (more topics, weaker topical signal) so checkpoint quality
    # spreads across the training run instead of saturating immediately
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries, n_topics=60,
        vocab=1009, topic_frac_p=0.35, topic_frac_q=0.5)
    # "BM25": lexical overlap with vocabulary mismatch (dropped query tokens)
    # — misses some same-topic hard negatives, so its subsets track the full
    # curve measurably worse than the strong run's (a real quality gap, not
    # the 1e-4 coin flip the un-dropped scorer produced on this corpus).
    weak = corpus_lib.lexical_baseline_run(ds, k=max(depths), drop_frac=0.4)
    # "TCT": topic oracle + idf-overlap tie-break — DR-like, so its subsets
    # keep the hard negatives a trained DR actually confuses
    strong = corpus_lib.oracle_noisy_baseline_run(ds, noise=0.3,
                                                  overlap_weight=0.75,
                                                  k=max(depths))
    spec = toy_spec(ds.vocab)
    # low lr: checkpoint quality spreads over the run (paper Fig. 2 shape)
    _, snapshots = train_toy_dr(ds, spec, steps=n_ckpts * steps_per_ckpt,
                                snapshot_every=steps_per_ckpt, seed=seed,
                                lr=0.04)
    vcfg = ValidationConfig(metrics=("MRR@10",), k=100, batch_size=128)

    def curve(sampler, baseline):
        pipe = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                                  vcfg, sampler=sampler,
                                  baseline_run=baseline)
        return ([pipe.validate_params(p, step=s).metrics["MRR@10"]
                 for s, p in snapshots], pipe.subset.size)

    full_curve, full_size = curve(FullCorpus(), None)
    out = {"full": {"curve": full_curve, "size": full_size}}
    for name, baseline in (("weak", weak), ("strong", strong)):
        for d in depths:
            c, size = curve(RunFileTopK(depth=d), baseline)
            rep = fidelity_report(full_curve, c)
            out[f"{name}_top{d}"] = {"curve": c, "size": size, **rep}
    return out


def run_precision(corpus_size: int = 2000, n_queries: int = 60,
                  n_ckpts: int = 6, steps_per_ckpt: int = 10,
                  depths=(10, 100), seed: int = 0):
    """Precision x subset-depth fidelity sweep (PR-6): does quantized
    scoring preserve the checkpoint-ranking signal the way subset sampling
    does?  Every (score_dtype, depth) cell's curve is rank-correlated
    against the f32 FULL-corpus run — the two fidelity axes (data subset,
    compute precision) land in the same report so their costs compose
    visibly."""
    ds = corpus_lib.synthetic_retrieval_dataset(
        seed, n_passages=corpus_size, n_queries=n_queries, n_topics=60,
        vocab=1009, topic_frac_p=0.35, topic_frac_q=0.5)
    strong = corpus_lib.oracle_noisy_baseline_run(ds, noise=0.3,
                                                  overlap_weight=0.75,
                                                  k=max(depths))
    spec = toy_spec(ds.vocab)
    _, snapshots = train_toy_dr(ds, spec, steps=n_ckpts * steps_per_ckpt,
                                snapshot_every=steps_per_ckpt, seed=seed,
                                lr=0.04)

    def curve(score_dtype, sampler, baseline):
        vcfg = ValidationConfig(metrics=("MRR@10",), k=100, batch_size=128,
                                score_dtype=score_dtype)
        pipe = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                                  vcfg, sampler=sampler,
                                  baseline_run=baseline)
        return ([pipe.validate_params(p, step=s).metrics["MRR@10"]
                 for s, p in snapshots], pipe.subset.size)

    full_f32, full_size = curve("f32", FullCorpus(), None)
    out = {"f32_full": {"curve": full_f32, "size": full_size,
                        "spearman": 1.0, "kendall_tau": 1.0,
                        "mean_delta": 0.0}}
    for dt in ("f32", "bf16", "int8"):
        cells = [("full", FullCorpus(), None)] if dt != "f32" else []
        cells += [(f"top{d}", RunFileTopK(depth=d), strong) for d in depths]
        for label, sampler, baseline in cells:
            c, size = curve(dt, sampler, baseline)
            out[f"{dt}_{label}"] = {"curve": c, "size": size,
                                    **fidelity_report(full_f32, c)}
    return out


def main():
    out = run()
    full = out["full"]["curve"]
    print("name,subset,size,spearman,kendall,mean_delta,best_agree,"
          "always_over")
    for key, rec in out.items():
        if key == "full":
            continue
        print(f"fidelity,{key},{rec['size']},{rec['spearman']:.3f},"
              f"{rec['kendall_tau']:.3f},{rec['mean_delta']:.4f},"
              f"{rec['best_ckpt_agreement']:.0f},"
              f"{rec['always_overestimates']:.0f}")
    print(f"fidelity,full,{out['full']['size']},1.000,1.000,0.0,1,0")
    print("fidelity_curve,full," + ",".join(f"{v:.4f}" for v in full))
    for key in (k for k in out if k != "full"):
        print(f"fidelity_curve,{key}," +
              ",".join(f"{v:.4f}" for v in out[key]["curve"]))
    # the paper's claims, as assertions on the synthetic reproduction:
    weak100 = out["weak_top100"]
    strong100 = out["strong_top100"]
    assert weak100["spearman"] > 0.7, "subset must preserve the trend"
    assert weak100["mean_delta"] >= 0, "subset must overestimate"
    # a real margin, not a 1e-6 tie-break: the weak run's vocabulary
    # mismatch makes its subsets miss hard negatives the strong run keeps
    assert strong100["mean_delta"] < weak100["mean_delta"] - 1e-3, \
        "stronger baseline subsets track the full curve closer"

    # -- precision x subset-depth sweep (PR-6) -----------------------------
    pout = run_precision()
    print("name,cell,size,spearman,kendall,mean_delta")
    for key, rec in pout.items():
        print(f"fidelity_precision,{key},{rec['size']},"
              f"{rec['spearman']:.3f},{rec['kendall_tau']:.3f},"
              f"{rec['mean_delta']:.4f}")
    # narrow precision on the FULL corpus must preserve the checkpoint
    # ranking almost perfectly — precision loss is far gentler than subset
    # loss, which is the whole point of offering it as a cheaper knob
    for dt in ("bf16", "int8"):
        assert pout[f"{dt}_full"]["spearman"] >= 0.9, \
            f"{dt} full-corpus curve must rank-track the f32 run " \
            f"(spearman={pout[f'{dt}_full']['spearman']:.3f})"
        # composed axes: quantized subset validation still preserves trend
        assert pout[f"{dt}_top100"]["spearman"] >= 0.7, \
            f"{dt} top-100 subset curve must preserve the trend " \
            f"(spearman={pout[f'{dt}_top100']['spearman']:.3f})"
    return out


if __name__ == "__main__":
    main()
